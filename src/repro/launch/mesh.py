"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on the CPU container.

Axis semantics:
  pod    — inter-pod data parallelism (and the pipeline axis when PP is on)
  data   — within-pod data parallelism + ZeRO sharding of params/optimizer
  model  — tensor/expert parallelism (and sequence parallelism for long
           activations)
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(model_parallelism: int = 16, devices=None):
    """Elastic variant: whatever devices are alive, shaped (data, model).

    Used by checkpoint-restore after a topology change: data-parallel size
    follows the live device count (model parallelism is fixed by the
    parameter sharding layout).
    """
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    model = min(model_parallelism, n)
    while n % model:
        model -= 1
    data = n // model
    dev_array = np.asarray(devices).reshape(data, model)
    return jax.sharding.Mesh(dev_array, ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod folds into data parallelism)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
