"""Training driver: ``python -m repro.launch.train --arch <id> [options]``.

Builds the arch's model (reduced or full), the data stream, sharded train
step (when >1 device), and runs the fault-tolerant loop with checkpointing.
The CPU container trains reduced configs (see --preset smoke); the same
driver lowers the full configs on a real fleet.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.train.data import RecsysStream, SampledGraphStream, TokenStream
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.trainstep import make_train_step
from repro.utils import get_logger

log = get_logger("launch.train")


def _stream_for(arch, cfg, batch_example, args):
    if arch.family == "lm":
        b, s = batch_example["tokens"].shape
        return TokenStream(vocab=cfg.vocab, batch=args.batch or b,
                           seq=args.seq or s, seed=args.seed)
    if arch.family == "recsys":
        return RecsysStream(n_dense=cfg.n_dense, n_sparse=cfg.n_sparse,
                            hotness=cfg.hotness,
                            vocab_sizes=cfg.vocab_sizes,
                            batch=args.batch or 64, seed=args.seed)
    # gnn: sampled stream over a synthetic graph
    d_feat = getattr(cfg, "d_feat", getattr(cfg, "d_node_in", 16))
    n_classes = getattr(cfg, "n_classes", 4)
    return SampledGraphStream(n_nodes=5000, avg_degree=8, d_feat=d_feat,
                              n_classes=n_classes,
                              batch_nodes=args.batch or 64, fanout=[5, 3],
                              seed=args.seed)


def _init_for(arch, cfg, key):
    if arch.family == "lm":
        from repro.models import transformer

        return transformer.init_params(key, cfg)
    if arch.family == "recsys":
        from repro.models.recsys import dlrm

        return dlrm.init_params(key, cfg)
    from repro.models.gnn import dimenet, gcn, meshgraphnet, pna

    mod = {"dimenet": dimenet, "gcn-cora": gcn, "meshgraphnet": meshgraphnet,
           "pna": pna}[arch.name]
    return mod.init_params(key, cfg)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="runs/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if arch.family == "engine":
        raise SystemExit("use repro.launch.serve for the engine")
    if args.preset == "smoke":
        cfg, batch_example = arch.smoke()
        if arch.family == "gnn":
            # sampled stream layout (node features, not molecule layout)
            if args.arch in ("dimenet", "meshgraphnet"):
                raise SystemExit(
                    f"{args.arch} smoke training uses the molecule layout; "
                    "run examples/gnn_training.py instead")
    else:
        cfg, batch_example = arch.config, None
    params = _init_for(arch, cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    log.info("arch=%s params=%.3fM", args.arch, n_params / 1e6)

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps,
                        grad_compress=args.grad_compress)
    opt_state = adamw_init(params, opt_cfg)
    stream = _stream_for(arch, cfg, batch_example, args)
    step = jax.jit(make_train_step(arch.loss_fn, cfg, opt_cfg,
                                   microbatches=args.microbatches))
    trainer = Trainer(step, stream,
                      LoopConfig(total_steps=args.steps,
                                 ckpt_every=args.ckpt_every,
                                 ckpt_dir=f"{args.ckpt_dir}/{args.arch}"),
                      params, opt_state)
    end = trainer.fit()
    last = trainer.metrics_log[-1] if trainer.metrics_log else {}
    log.info("done at step %d: %s", end, last)
    print(f"final step={end} loss={last.get('loss')}")


if __name__ == "__main__":
    main()
