"""Fused gather + segment-sum kernel (EmbeddingBag-sum / GNN aggregation).

Shared between the engine substrate and the model zoo (DLRM embedding
lookups, GCN/PNA message aggregation).  The kernel operates on the
*fixed-hotness* layout the data pipeline produces: per-segment index tiles
``idx int32 [S, K]`` (padded with -1), summing ``table[idx[s, k]]`` over k
into ``out[s]``.

Tiling: grid (segment tiles × feature tiles).  The feature dimension is
blocked at 128 lanes (VPU width); the table block for the active feature
tile is staged in VMEM and rows are gathered from it.  ops.py falls back to
the XLA scatter-add reference when the table exceeds the VMEM budget
(row-sharded tables at scale use one kernel call per shard).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VMEM_TABLE_ROWS = 1 << 17  # fall back above this many rows


def _kernel(table_ref, idx_ref, w_ref, o_ref):
    table = table_ref[...]  # [V, TD]
    idx = idx_ref[...]  # [TS, K]
    w = w_ref[...]  # [TS, K]
    v = table.shape[0]
    rows = jnp.take(table, jnp.clip(idx, 0, v - 1).reshape(-1), axis=0)
    rows = rows.reshape(idx.shape[0], idx.shape[1], table.shape[1])
    mask = (idx >= 0).astype(rows.dtype)[:, :, None]
    o_ref[...] = jnp.sum(rows * mask * w[:, :, None].astype(rows.dtype), axis=1)


@partial(jax.jit, static_argnames=("interpret", "seg_tile", "feat_tile"))
def segment_gather_fixed_pallas(
    table: jax.Array,  # [V, D]
    idx: jax.Array,  # int32 [S, K], -1 padded
    weights: jax.Array | None = None,  # [S, K]
    *,
    interpret: bool = False,
    seg_tile: int = 256,
    feat_tile: int = 128,
) -> jax.Array:
    v, d = table.shape
    s, k = idx.shape
    if weights is None:
        weights = jnp.ones((s, k), dtype=table.dtype)
    ts = min(seg_tile, max(1, s))
    td = min(feat_tile, d)
    pad_s = (-s) % ts
    pad_d = (-d) % td
    if pad_s:
        idx = jnp.pad(idx, ((0, pad_s), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, pad_s), (0, 0)))
    if pad_d:
        table = jnp.pad(table, ((0, 0), (0, pad_d)))
    sp, dp = idx.shape[0], table.shape[1]
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((sp, dp), table.dtype),
        grid=(sp // ts, dp // td),
        in_specs=[
            pl.BlockSpec((v, td), lambda i, j: (0, j)),
            pl.BlockSpec((ts, k), lambda i, j: (i, 0)),
            pl.BlockSpec((ts, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ts, td), lambda i, j: (i, j)),
        interpret=interpret,
    )(table, idx, weights)
    return out[:s, :d]


def segment_gather_sum_pallas(
    table: jax.Array,
    indices: jax.Array,  # int32 [E]
    segments: jax.Array,  # int32 [E]
    num_segments: int,
    weights: jax.Array | None = None,
    *,
    interpret: bool = False,
    max_hotness: int = 32,
) -> jax.Array:
    """Ragged entry point: regroups (indices, segments) into the fixed-hotness
    layout (hotness bound is static), else falls back to the ref.

    Correctness under the bound: entries whose within-segment rank exceeds
    ``max_hotness`` would be dropped, so the regrouped path is only used when
    E ≤ S·max_hotness AND the scatter preserves all entries — verified by a
    count check folded into a fallback select.
    """
    from repro.kernels.ref import segment_gather_sum_ref

    e = indices.shape[0]
    if (table.shape[0] > VMEM_TABLE_ROWS
            or e > num_segments * max_hotness or e == 0):
        return segment_gather_sum_ref(table, indices, segments, num_segments,
                                      weights=weights)
    order = jnp.argsort(segments)
    seg_s = segments[order]
    idx_s = indices[order]
    w_s = weights[order] if weights is not None else None
    seg_starts = jnp.searchsorted(seg_s, jnp.arange(num_segments))
    rank = jnp.arange(e, dtype=jnp.int32) - seg_starts[seg_s].astype(jnp.int32)
    fits = jnp.all(rank < max_hotness)
    rank_c = jnp.clip(rank, 0, max_hotness - 1)
    dense_idx = jnp.full((num_segments, max_hotness), -1, dtype=jnp.int32)
    dense_idx = dense_idx.at[seg_s, rank_c].set(idx_s)
    dense_w = None
    if w_s is not None:
        dense_w = jnp.zeros((num_segments, max_hotness), dtype=table.dtype)
        dense_w = dense_w.at[seg_s, rank_c].set(w_s)
    fast = segment_gather_fixed_pallas(table, dense_idx, dense_w,
                                       interpret=interpret)
    slow = segment_gather_sum_ref(table, indices, segments, num_segments,
                                  weights=weights)
    return jnp.where(fits, fast, slow)
