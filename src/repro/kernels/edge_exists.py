"""IsJoinable kernel: batched binary search over CSR adjacency slices.

Each lane owns one (candidate, non-tree-edge) probe: search ``target[i]``
within the sorted slice ``nbr[lo[i]:hi[i])``.  The adjacency array is staged
into VMEM as one block (the executor guarantees the per-edge-label array it
passes fits the VMEM budget; ops.py falls back to the XLA-gather reference
above that bound), and every lane runs the same log2(max_deg) halving rounds
— a classic SIMT-style binary search with no serial divergence.

nbr: int32 [m] (VMEM-resident block), lo/hi/target: int32 [B] → bool [B].
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget for the adjacency block (int32 words).  ~4 MiB leaves room for
# the query tiles and double buffering in 16 MiB VMEM.
VMEM_NBR_BOUND = 1 << 20


def _kernel(nbr_ref, lo_ref, hi_ref, tgt_ref, o_ref, *, n_iters: int):
    nbr = nbr_ref[...]  # [m]
    m = nbr.shape[0]
    lo0 = lo_ref[...]
    hi0 = hi_ref[...]
    tgt = tgt_ref[...]

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        v = jnp.take(nbr, jnp.clip(mid, 0, m - 1))
        right = v < tgt
        return jnp.where(right, mid + 1, lo), jnp.where(right, hi, mid)

    lo_f, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    hit = jnp.take(nbr, jnp.clip(lo_f, 0, m - 1)) == tgt
    o_ref[...] = hit & (lo_f < hi0) & (lo0 < hi0)


@partial(jax.jit, static_argnames=("n_iters", "interpret", "tile"))
def edge_exists_pallas(
    nbr: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    target: jax.Array,
    *,
    n_iters: int = 32,
    interpret: bool = False,
    tile: int = 1024,
) -> jax.Array:
    from repro.kernels.ref import edge_exists_ref

    if nbr.shape[0] > VMEM_NBR_BOUND:
        # adjacency too large for a VMEM block: XLA-gather path
        return edge_exists_ref(nbr, lo, hi, target, n_iters=n_iters)
    (b,) = lo.shape
    t = min(tile, max(1, b))
    pad = (-b) % t
    if pad:
        lo = jnp.pad(lo, (0, pad))
        hi = jnp.pad(hi, (0, pad))  # lo==hi==0 → miss
        target = jnp.pad(target, (0, pad), constant_values=-1)
    bp = lo.shape[0]
    out = pl.pallas_call(
        partial(_kernel, n_iters=n_iters),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.bool_),
        grid=(bp // t,),
        in_specs=[
            pl.BlockSpec(nbr.shape, lambda i: (0,)),  # whole array each step
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        interpret=interpret,
    )(nbr.astype(jnp.int32), lo.astype(jnp.int32), hi.astype(jnp.int32),
      target.astype(jnp.int32))
    return out[:b]
