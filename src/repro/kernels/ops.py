"""Dispatch layer: Pallas TPU kernels on TPU, jnp oracles elsewhere.

``REPRO_KERNELS`` env var forces a backend: ``ref`` (pure jnp),
``pallas_interpret`` (Pallas kernels in interpret mode — used by the kernel
test suite on CPU), ``pallas`` (compiled, TPU).  Default: ``pallas`` on TPU
backends, ``ref`` otherwise.
"""

from __future__ import annotations

import os
from functools import lru_cache

import jax

from repro.kernels import ref as _ref


@lru_cache(maxsize=1)
def backend() -> str:
    forced = os.environ.get("REPRO_KERNELS")
    if forced:
        return forced
    try:
        platform = jax.default_backend()
    except Exception:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "ref"


def _use_pallas() -> bool:
    return backend() in ("pallas", "pallas_interpret")


def _interpret() -> bool:
    return backend() == "pallas_interpret" or (
        backend() == "pallas" and jax.default_backend() != "tpu"
    )


# --------------------------------------------------------------------------


def edge_exists(nbr, lo, hi, target, n_iters: int = 32):
    if _use_pallas():
        from repro.kernels.edge_exists import edge_exists_pallas

        return edge_exists_pallas(nbr, lo, hi, target, n_iters=n_iters,
                                  interpret=_interpret())
    return _ref.edge_exists_ref(nbr, lo, hi, target, n_iters=n_iters)


def tile_membership(a, b):
    if _use_pallas():
        from repro.kernels.sorted_intersect import tile_membership_pallas

        return tile_membership_pallas(a, b, interpret=_interpret())
    return _ref.tile_membership_ref(a, b)


def bitmap_superset(bitmap, required):
    if _use_pallas():
        from repro.kernels.bitmap_filter import bitmap_superset_pallas

        return bitmap_superset_pallas(bitmap, required, interpret=_interpret())
    return _ref.bitmap_superset_ref(bitmap, required)


def signature_filter(sig, v, required):
    """Neighborhood-signature prune probe: gather candidate rows from the
    resident signature table and superset-test them against the query
    vertex's required signature.  See
    :func:`repro.kernels.ref.signature_filter_ref` for semantics."""
    if _use_pallas():
        from repro.kernels import signature_filter as _sf

        if (sig.size <= _sf.VMEM_SIG_BOUND
                and v.shape[0] <= _sf.VMEM_ROWS_BOUND):
            return _sf.signature_filter_pallas(sig, v, required,
                                               interpret=_interpret())
    return _ref.signature_filter_ref(sig, v, required)


def segment_gather_sum(table, indices, segments, num_segments, weights=None):
    if _use_pallas():
        from repro.kernels.segment_gather import segment_gather_sum_pallas

        return segment_gather_sum_pallas(
            table, indices, segments, num_segments, weights=weights,
            interpret=_interpret(),
        )
    return _ref.segment_gather_sum_ref(table, indices, segments, num_segments,
                                       weights=weights)


def ragged_expand(offsets, degrees, capacity: int):
    # pure-jnp always: the searchsorted lowers well on all backends
    return _ref.ragged_expand_ref(offsets, degrees, capacity)


def delta_merge(base_nbr, delta_nbr, tomb_nbr, b_start, b_deg, d_start,
                t_lo, t_hi, j, valid, n_iters: int = 32):
    """Live-store expansion: resolve merged base+delta adjacency slots and
    mask tombstoned base edges.  See
    :func:`repro.kernels.ref.delta_merge_ref` for semantics."""
    if _use_pallas():
        from repro.kernels.delta_merge import delta_merge_pallas

        return delta_merge_pallas(base_nbr, delta_nbr, tomb_nbr, b_start,
                                  b_deg, d_start, t_lo, t_hi, j, valid,
                                  n_iters=n_iters, interpret=_interpret())
    return _ref.delta_merge_ref(base_nbr, delta_nbr, tomb_nbr, b_start,
                                b_deg, d_start, t_lo, t_hi, j, valid,
                                n_iters=n_iters)


def delta_merge_labeled(base_nbr, base_lab, delta_nbr, delta_lab, tomb_key,
                        b_start, b_deg, d_start, t_lo, t_hi, j, valid,
                        n_elabels: int, n_iters: int = 32):
    """Predicate-variable variant of :func:`delta_merge` (jnp oracle on
    every backend — the dynamic-label path is cold)."""
    return _ref.delta_merge_labeled_ref(base_nbr, base_lab, delta_nbr,
                                        delta_lab, tomb_key, b_start, b_deg,
                                        d_start, t_lo, t_hi, j, valid,
                                        n_elabels, n_iters=n_iters)


def expand_filter_compact(nbr, bitmap, start, deg, offs, label_mask, bound_id,
                          capacity: int):
    """Fused ragged expansion + label filter + compaction (the executor's
    per-step hot path).  Returns ``(v_out, row_out, count)``; see
    :func:`repro.kernels.ref.expand_filter_compact_ref` for semantics."""
    if _use_pallas():
        from repro.kernels import expand_filter as _ef

        if (nbr.shape[0] <= _ef.VMEM_NBR_BOUND
                and bitmap.size <= _ef.VMEM_BITMAP_BOUND
                and offs.shape[0] <= _ef.VMEM_ROWS_BOUND
                and capacity <= _ef.VMEM_ROWS_BOUND):
            return _ef.expand_filter_compact_pallas(
                nbr, bitmap, start, deg, offs, label_mask, bound_id,
                capacity=capacity, interpret=_interpret())
    return _ref.expand_filter_compact_ref(nbr, bitmap, start, deg, offs,
                                          label_mask, bound_id, capacity)
