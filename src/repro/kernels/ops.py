"""Dispatch layer: Pallas TPU kernels on TPU, jnp oracles elsewhere.

``REPRO_KERNELS`` env var forces a backend: ``ref`` (pure jnp),
``pallas_interpret`` (Pallas kernels in interpret mode — used by the kernel
test suite on CPU), ``pallas`` (compiled, TPU).  Default: ``pallas`` on TPU
backends, ``ref`` otherwise.
"""

from __future__ import annotations

import os
from functools import lru_cache

import jax

from repro.kernels import ref as _ref


@lru_cache(maxsize=1)
def backend() -> str:
    forced = os.environ.get("REPRO_KERNELS")
    if forced:
        return forced
    try:
        platform = jax.default_backend()
    except Exception:
        platform = "cpu"
    return "pallas" if platform == "tpu" else "ref"


def _use_pallas() -> bool:
    return backend() in ("pallas", "pallas_interpret")


def _interpret() -> bool:
    return backend() == "pallas_interpret" or (
        backend() == "pallas" and jax.default_backend() != "tpu"
    )


# --------------------------------------------------------------------------


def edge_exists(nbr, lo, hi, target, n_iters: int = 32):
    if _use_pallas():
        from repro.kernels.edge_exists import edge_exists_pallas

        return edge_exists_pallas(nbr, lo, hi, target, n_iters=n_iters,
                                  interpret=_interpret())
    return _ref.edge_exists_ref(nbr, lo, hi, target, n_iters=n_iters)


def tile_membership(a, b):
    if _use_pallas():
        from repro.kernels.sorted_intersect import tile_membership_pallas

        return tile_membership_pallas(a, b, interpret=_interpret())
    return _ref.tile_membership_ref(a, b)


def bitmap_superset(bitmap, required):
    if _use_pallas():
        from repro.kernels.bitmap_filter import bitmap_superset_pallas

        return bitmap_superset_pallas(bitmap, required, interpret=_interpret())
    return _ref.bitmap_superset_ref(bitmap, required)


def segment_gather_sum(table, indices, segments, num_segments, weights=None):
    if _use_pallas():
        from repro.kernels.segment_gather import segment_gather_sum_pallas

        return segment_gather_sum_pallas(
            table, indices, segments, num_segments, weights=weights,
            interpret=_interpret(),
        )
    return _ref.segment_gather_sum_ref(table, indices, segments, num_segments,
                                       weights=weights)


def ragged_expand(offsets, degrees, capacity: int):
    # pure-jnp always: the searchsorted lowers well on all backends
    return _ref.ragged_expand_ref(offsets, degrees, capacity)
