"""Neighborhood-signature prune kernel: fused gather + superset probe.

Candidate pruning tests each frontier vertex's folded predicate signature
(:mod:`repro.index.signature`) against the query vertex's required
signature.  Unlike :mod:`repro.kernels.bitmap_filter` — whose rows are
already gathered — the signature table stays resident in VMEM and the
kernel gathers rows by candidate id itself, so the probe composes with
the executor step loop without materializing a [B, 2W] gather first.

sig: uint32 [V, 2W], v: int32 [B], required: uint32 [2W] → bool [B].
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# whole-array VMEM residency bounds (uint32 words / candidate rows)
VMEM_SIG_BOUND = 1 << 20
VMEM_ROWS_BOUND = 1 << 19


def _kernel(sig_ref, v_ref, req_ref, o_ref):
    sig = sig_ref[...]  # [V, 2W] resident table
    v = jnp.clip(v_ref[...], 0, sig.shape[0] - 1)  # [T]
    rows = jnp.take(sig, v, axis=0)  # [T, 2W]
    req = req_ref[...]  # [1, 2W]
    o_ref[...] = jnp.all((rows & req) == req, axis=-1)


@partial(jax.jit, static_argnames=("interpret", "tile"))
def signature_filter_pallas(
    sig: jax.Array, v: jax.Array, required: jax.Array, *,
    interpret: bool = False, tile: int = 1024
) -> jax.Array:
    b = v.shape[0]
    nv, w = sig.shape
    t = min(tile, max(1, b))
    pad = (-b) % t
    if pad:
        v = jnp.pad(v, (0, pad))
    bp = v.shape[0]
    req2 = required.reshape(1, w)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.bool_),
        grid=(bp // t,),
        in_specs=[
            pl.BlockSpec((nv, w), lambda i: (0, 0)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        interpret=interpret,
    )(sig, v, req2)
    return out[:b]
