"""Delta-merge kernel: base/delta CSR slot resolution + tombstone masking.

The live store's executor expands each binding-table row over the logical
adjacency list ``base_slice ++ delta_slice``; this kernel resolves one
output slot per lane — gather from the base or delta block depending on the
within-row position — and masks base candidates that appear in the sorted
tombstone slice via the same SIMT-style binary search as
:mod:`repro.kernels.edge_exists` (all three adjacency arrays staged into
VMEM as whole blocks; deltas are small by construction, and ops.py falls
back to the jnp oracle past the VMEM bound).

Oracle of record: :func:`repro.kernels.ref.delta_merge_ref`.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# combined VMEM budget for the three adjacency blocks (int32 words)
VMEM_NBR_BOUND = 1 << 20


def _kernel(base_ref, delta_ref, tomb_ref, bs_ref, bd_ref, ds_ref,
            tlo_ref, thi_ref, j_ref, valid_ref, v_ref, ok_ref, *,
            n_iters: int):
    base = base_ref[...]
    delta = delta_ref[...]
    tomb = tomb_ref[...]
    mb = base.shape[0]
    md = delta.shape[0]
    mt = tomb.shape[0]
    bs = bs_ref[...]
    bd = bd_ref[...]
    ds = ds_ref[...]
    j = j_ref[...]
    valid = valid_ref[...]
    is_base = j < bd
    v_b = jnp.take(base, jnp.clip(bs + j, 0, mb - 1))
    v_d = jnp.take(delta, jnp.clip(ds + (j - bd), 0, md - 1))
    v = jnp.where(is_base, v_b, v_d)

    lo0 = tlo_ref[...]
    hi0 = thi_ref[...]

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        t = jnp.take(tomb, jnp.clip(mid, 0, mt - 1))
        right = t < v
        return jnp.where(right, mid + 1, lo), jnp.where(right, hi, mid)

    lo_f, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    dead = (jnp.take(tomb, jnp.clip(lo_f, 0, mt - 1)) == v) & \
        (lo_f < hi0) & (lo0 < hi0) & is_base
    v_ref[...] = jnp.where(valid, v, -1)
    ok_ref[...] = valid & ~dead


@partial(jax.jit, static_argnames=("n_iters", "interpret", "tile"))
def delta_merge_pallas(
    base_nbr: jax.Array,
    delta_nbr: jax.Array,
    tomb_nbr: jax.Array,
    b_start: jax.Array,
    b_deg: jax.Array,
    d_start: jax.Array,
    t_lo: jax.Array,
    t_hi: jax.Array,
    j: jax.Array,
    valid: jax.Array,
    *,
    n_iters: int = 32,
    interpret: bool = False,
    tile: int = 1024,
) -> tuple[jax.Array, jax.Array]:
    from repro.kernels.ref import delta_merge_ref

    total = base_nbr.shape[0] + delta_nbr.shape[0] + tomb_nbr.shape[0]
    if total > VMEM_NBR_BOUND:
        return delta_merge_ref(base_nbr, delta_nbr, tomb_nbr, b_start, b_deg,
                               d_start, t_lo, t_hi, j, valid, n_iters=n_iters)

    def pad1(a):  # zero-length blocks break BlockSpec; pad to one slot
        return a if a.shape[0] else jnp.full(1, -1, jnp.int32)

    base_nbr, delta_nbr, tomb_nbr = map(pad1, (base_nbr, delta_nbr, tomb_nbr))
    (k,) = j.shape
    t = min(tile, max(1, k))
    pad = (-k) % t
    if pad:
        b_start = jnp.pad(b_start, (0, pad))
        b_deg = jnp.pad(b_deg, (0, pad))
        d_start = jnp.pad(d_start, (0, pad))
        t_lo = jnp.pad(t_lo, (0, pad))
        t_hi = jnp.pad(t_hi, (0, pad))
        j = jnp.pad(j, (0, pad))
        valid = jnp.pad(valid, (0, pad))  # False → slot resolves to -1
    kp = j.shape[0]
    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,))  # noqa: E731
    lane = pl.BlockSpec((t,), lambda i: (i,))
    v, ok = pl.pallas_call(
        partial(_kernel, n_iters=n_iters),
        out_shape=(jax.ShapeDtypeStruct((kp,), jnp.int32),
                   jax.ShapeDtypeStruct((kp,), jnp.bool_)),
        grid=(kp // t,),
        in_specs=[full(base_nbr), full(delta_nbr), full(tomb_nbr),
                  lane, lane, lane, lane, lane, lane, lane],
        out_specs=(lane, lane),
        interpret=interpret,
    )(base_nbr.astype(jnp.int32), delta_nbr.astype(jnp.int32),
      tomb_nbr.astype(jnp.int32), b_start.astype(jnp.int32),
      b_deg.astype(jnp.int32), d_start.astype(jnp.int32),
      t_lo.astype(jnp.int32), t_hi.astype(jnp.int32), j.astype(jnp.int32),
      valid)
    return v[:k], ok[:k]
