"""Label / NLF filter kernel: packed-bitmap superset probe.

The two-attribute vertex model stores L(v) as packed uint32 words; a filter
probe is ``(bitmap[v] & required) == required`` over all words.  One VPU
pass per row tile: the word dimension (≤ a few words for real ontologies)
is reduced in registers.

bitmap: uint32 [B, W], required: uint32 [W] → bool [B].
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(bm_ref, req_ref, o_ref):
    bm = bm_ref[...]  # [TB, W]
    req = req_ref[...]  # [1, W]
    o_ref[...] = jnp.all((bm & req) == req, axis=-1)


@partial(jax.jit, static_argnames=("interpret", "tile"))
def bitmap_superset_pallas(
    bitmap: jax.Array, required: jax.Array, *, interpret: bool = False,
    tile: int = 1024
) -> jax.Array:
    b, w = bitmap.shape
    t = min(tile, max(1, b))
    pad = (-b) % t
    if pad:
        bitmap = jnp.pad(bitmap, ((0, pad), (0, 0)))
    bp = bitmap.shape[0]
    req2 = required.reshape(1, w)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.bool_),
        grid=(bp // t,),
        in_specs=[
            pl.BlockSpec((t, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        interpret=interpret,
    )(bitmap, req2)
    return out[:b]
