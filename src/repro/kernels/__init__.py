"""Pallas TPU kernels for the engine's compute hot-spots.

The paper's profiling singles out ExploreCandidateRegion and SubgraphSearch
(IsJoinable in particular) as the dominating costs; the corresponding
vectorized primitives get kernels here:

- ``edge_exists``       — batched binary search over CSR slices (IsJoinable)
- ``sorted_intersect``  — tiled compare-all membership (+INT, VPU-shaped)
- ``bitmap_filter``     — packed-bitmap superset probes (label / NLF filters)
- ``segment_gather``    — fused gather + segment-sum (EmbeddingBag / GNN
                          aggregation; shared with the model zoo)

Every kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` dispatches by
backend (Pallas on TPU, interpret mode for CPU validation, jnp otherwise).
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
