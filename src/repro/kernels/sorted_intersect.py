"""+INT bulk-join kernel: per-row compare-all membership in VMEM tiles.

The paper's +INT optimization replaces per-candidate binary-search IsJoinable
probes with one bulk intersection between the candidate set C_R and the
already-matched vertex's adjacency list.  A CPU executes that as a sorted
merge; a merge is inherently sequential, so on TPU we reshape the insight:
both lists sit in VMEM as fixed tiles and the VPU evaluates the full
TA × TB equality cross-product per row — O(TA·TB) trivially-vectorized
compares beat O(TA·log TB) serial-dependency probes for the tile sizes the
executor uses (TB ≤ 256).

a: int32 [R, TA]  candidate tiles (padding = any negative value)
b: int32 [R, TB]  adjacency tiles (padding = any negative value)
out: bool [R, TA] — out[i, j] ⇔ a[i, j] ∈ b[i, :]
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # [TR, TA]
    b = b_ref[...]  # [TR, TB]
    eq = (a[:, :, None] == b[:, None, :]) & (a[:, :, None] >= 0)
    o_ref[...] = jnp.any(eq, axis=-1)


@partial(jax.jit, static_argnames=("interpret", "row_tile"))
def tile_membership_pallas(
    a: jax.Array, b: jax.Array, *, interpret: bool = False, row_tile: int = 256
) -> jax.Array:
    assert a.ndim == 2 and b.ndim == 2 and a.shape[0] == b.shape[0]
    r, ta = a.shape
    tb = b.shape[1]
    tr = min(row_tile, max(1, r))
    pad = (-r) % tr
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)), constant_values=-1)
        b = jnp.pad(b, ((0, pad), (0, 0)), constant_values=-1)
    rp = a.shape[0]
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((rp, ta), jnp.bool_),
        grid=(rp // tr,),
        in_specs=[
            pl.BlockSpec((tr, ta), lambda i: (i, 0)),
            pl.BlockSpec((tr, tb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tr, ta), lambda i: (i, 0)),
        interpret=interpret,
    )(a, b)
    return out[:r]
