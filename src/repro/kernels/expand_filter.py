"""Fused expand/filter/compact kernel: the executor's hottest step in one
VMEM pass.

Each binding-table step is a ragged CSR expansion (every surviving row
emits ``deg[i]`` candidate vertices), a label filter (packed-bitmap
superset probe per candidate), and a compaction of survivors to a prefix.
The reference path materializes 6+ capacity-sized intermediates (row ids,
within-row offsets, validity, gathered neighbors, gathered bitmap words,
scatter positions) in HBM between XLA ops.  This kernel streams one output
tile at a time through VMEM instead:

  1. binary-search the exclusive-cumsum ``offs`` to map output slots to
     source rows (the SIMT searchsorted trick, same shape as edge_exists),
  2. gather the candidate ``v = nbr[start[row] + j]`` and its label words,
  3. evaluate the superset / bound-id tests in registers,
  4. compact survivors inside the tile by sorting on the local prefix-sum
     rank, then append the tile to the global output at a running base
     carried across the (sequential) grid in SMEM scratch.

Tiles overwrite the junk tails of their predecessors, so the output is a
dense prefix of survivors followed by ``-1`` padding — exactly the layout
``_compact`` produces, with no capacity-sized scratch in HBM.

nbr: int32 [m], bitmap: uint32 [V, W], start/deg/offs: int32 [R],
label_mask: uint32 [W], bound_id: int32 [1]
→ (v_out int32 [capacity], row_out int32 [capacity], count int32 [1]).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# VMEM word budgets: adjacency + bitmap + row arrays must all be resident.
# ops.py falls back to the jnp reference above these bounds.
VMEM_NBR_BOUND = 1 << 20
VMEM_BITMAP_BOUND = 1 << 20
VMEM_ROWS_BOUND = 1 << 19


def _kernel(nbr_ref, bm_ref, start_ref, deg_ref, offs_ref, mask_ref, bid_ref,
            v_ref, r_ref, cnt_ref, base_ref, *, tile: int, n_iters: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        base_ref[0] = 0

    nbr = nbr_ref[...]
    offs = offs_ref[...]
    r_rows = offs.shape[0]
    m = nbr.shape[0]
    k0 = i * tile
    k = k0 + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0).reshape(tile)

    # row[k] = rightmost i with offs[i] <= k (offs[0] == 0, so row >= 0)
    def body(_, state):
        lo, hi = state
        mid = (lo + hi) >> 1
        le = jnp.take(offs, jnp.clip(mid, 0, r_rows - 1)) <= k
        return jnp.where(le, mid + 1, lo), jnp.where(le, hi, mid)

    lo_f, _ = jax.lax.fori_loop(
        0, n_iters, body,
        (jnp.zeros((tile,), jnp.int32), jnp.full((tile,), r_rows, jnp.int32)))
    row = jnp.clip(lo_f - 1, 0, r_rows - 1)

    d_row = jnp.take(deg_ref[...], row)
    j = k - jnp.take(offs, row)
    total = offs[r_rows - 1] + deg_ref[r_rows - 1]
    valid = (k < total) & (j >= 0) & (j < d_row)

    idx = jnp.clip(jnp.take(start_ref[...], row) + j, 0, m - 1)
    v = jnp.where(valid, jnp.take(nbr, idx), -1)

    bm = bm_ref[...]  # [V, W]
    req = mask_ref[...]  # [1, W]
    words = jnp.take(bm, jnp.clip(v, 0, bm.shape[0] - 1), axis=0)  # [tile, W]
    ok = valid & jnp.all((words & req) == req, axis=-1)
    bid = bid_ref[0]
    ok &= (bid < 0) | (v == bid)

    # intra-tile compaction: rank survivors by local prefix sum, sort the
    # (rank, v, row) triple so survivors land in the first local_count lanes
    oki = ok.astype(jnp.int32)
    rank = jnp.cumsum(oki) - 1
    local_count = jnp.sum(oki)
    key = jnp.where(ok, rank, tile)
    _, v_s, r_s = jax.lax.sort(
        (key, jnp.where(ok, v, -1), jnp.where(ok, row, -1)),
        num_keys=1, is_stable=True)

    # fill own slot range first (junk beyond the final count must read -1),
    # then append the compacted tile at the running base.  base <= k0, so
    # neither write can clobber an earlier tile's survivors.
    v_ref[pl.ds(k0, tile)] = jnp.full((tile,), -1, jnp.int32)
    r_ref[pl.ds(k0, tile)] = jnp.full((tile,), -1, jnp.int32)
    base = base_ref[0]
    v_ref[pl.ds(base, tile)] = v_s
    r_ref[pl.ds(base, tile)] = r_s
    base_ref[0] = base + local_count

    @pl.when(i == pl.num_programs(0) - 1)
    def _():
        cnt_ref[0] = base + local_count


@partial(jax.jit, static_argnames=("capacity", "interpret", "tile"))
def expand_filter_compact_pallas(
    nbr: jax.Array,
    bitmap: jax.Array,
    start: jax.Array,
    deg: jax.Array,
    offs: jax.Array,
    label_mask: jax.Array,
    bound_id: jax.Array,
    *,
    capacity: int,
    interpret: bool = False,
    tile: int = 512,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    (r,) = offs.shape
    w = bitmap.shape[1]
    t = min(tile, max(8, capacity))
    cap_p = capacity + (-capacity) % t
    n_iters = max(1, r).bit_length() + 1
    v_out, r_out, cnt = pl.pallas_call(
        partial(_kernel, tile=t, n_iters=n_iters),
        out_shape=(
            jax.ShapeDtypeStruct((cap_p,), jnp.int32),
            jax.ShapeDtypeStruct((cap_p,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ),
        grid=(cap_p // t,),
        in_specs=[
            pl.BlockSpec(nbr.shape, lambda i: (0,)),
            pl.BlockSpec(bitmap.shape, lambda i: (0, 0)),
            pl.BlockSpec(start.shape, lambda i: (0,)),
            pl.BlockSpec(deg.shape, lambda i: (0,)),
            pl.BlockSpec(offs.shape, lambda i: (0,)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((cap_p,), lambda i: (0,)),
            pl.BlockSpec((cap_p,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(nbr.astype(jnp.int32), bitmap, start.astype(jnp.int32),
      deg.astype(jnp.int32), offs.astype(jnp.int32),
      label_mask.reshape(1, w),
      jnp.asarray(bound_id, jnp.int32).reshape(1))
    return v_out[:capacity], r_out[:capacity], cnt[0]
