"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each Pallas kernel's test sweeps shapes
and dtypes asserting allclose against the function here.  The executor can
run entirely on these (``REPRO_KERNELS=ref``), which is also the path used
on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_exists_ref(
    nbr: jax.Array,  # int32 [m]    sorted adjacency (per (el) block, per-src runs)
    lo: jax.Array,  # int32 [B]    per-query slice start
    hi: jax.Array,  # int32 [B]    per-query slice end (exclusive)
    target: jax.Array,  # int32 [B]
    n_iters: int = 32,
) -> jax.Array:
    """Batched lower-bound binary search: target ∈ nbr[lo:hi)?  bool [B].

    This is the paper's original IsJoinable membership probe,
    O(log deg) per (candidate, non-tree edge) pair.
    """
    m = max(1, nbr.shape[0])

    def body(_, state):
        lo_, hi_ = state
        mid = (lo_ + hi_) >> 1
        v = nbr[jnp.clip(mid, 0, m - 1)]
        go_right = v < target
        return jnp.where(go_right, mid + 1, lo_), jnp.where(go_right, hi_, mid)

    lo0 = lo.astype(jnp.int32)
    hi0 = hi.astype(jnp.int32)
    lo_f, _ = jax.lax.fori_loop(0, n_iters, body, (lo0, hi0))
    found = (lo_f < hi0) & (nbr[jnp.clip(lo_f, 0, m - 1)] == target)
    return found & (lo0 < hi0)


def tile_membership_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Per-row compare-all membership: out[i, j] = a[i, j] ∈ b[i, :].

    a: int32 [R, TA] candidate tiles (padded with -1)
    b: int32 [R, TB] adjacency tiles (padded with -1)
    This is the +INT bulk-join primitive reshaped for the VPU: rather than a
    sequential sorted-merge (CPU-optimal), a TPU does the O(TA·TB) compare-all
    inside VMEM, which vectorizes perfectly for the tile sizes the executor
    uses.
    """
    eq = a[:, :, None] == b[:, None, :]
    return jnp.any(eq & (a[:, :, None] >= 0), axis=-1)


def bitmap_superset_ref(bitmap: jax.Array, required: jax.Array) -> jax.Array:
    """Row-wise superset test on packed uint32 bitmaps.

    bitmap: uint32 [B, W] per-candidate label (or NLF neighbor-type) words
    required: uint32 [W] the query-side mask
    returns bool [B]: (bitmap & required) == required for every word.
    """
    req = required[None, :]
    return jnp.all((bitmap & req) == req, axis=-1)


def signature_filter_ref(sig: jax.Array, v: jax.Array,
                         required: jax.Array) -> jax.Array:
    """Gather-then-superset probe on the neighborhood-signature index.

    sig: uint32 [V, 2W] per-vertex folded predicate signatures
    v: int32 [B] candidate vertex ids (out-of-range ids clip; callers mask
       invalid rows separately)
    required: uint32 [2W] the query vertex's required signature
    returns bool [B]: candidate's signature is a superset of required.
    """
    rows = jnp.take(sig, jnp.clip(v, 0, sig.shape[0] - 1), axis=0)
    req = required[None, :]
    return jnp.all((rows & req) == req, axis=-1)


def segment_gather_sum_ref(
    table: jax.Array,  # [V, D] embedding rows / node features
    indices: jax.Array,  # int32 [E] gather ids
    segments: jax.Array,  # int32 [E] destination segment per gathered row
    num_segments: int,
    weights: jax.Array | None = None,  # optional [E]
) -> jax.Array:
    """Fused gather + segment-sum (EmbeddingBag-sum / GNN aggregate oracle)."""
    rows = table[indices]
    if weights is not None:
        rows = rows * weights[:, None]
    return jax.ops.segment_sum(rows, segments, num_segments=num_segments)


def expand_filter_compact_ref(
    nbr: jax.Array,  # int32 [m]     CSR adjacency values
    bitmap: jax.Array,  # uint32 [V, W] packed vertex-label words
    start: jax.Array,  # int32 [R]    per-row adjacency slice start
    deg: jax.Array,  # int32 [R]      per-row slice length
    offs: jax.Array,  # int32 [R]     exclusive cumsum of deg
    label_mask: jax.Array,  # uint32 [W] required label words (0 = no filter)
    bound_id: jax.Array,  # int32 []   required vertex id (< 0 = no check)
    capacity: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused ragged CSR expansion + label-bitmap filter + compaction.

    Logical candidate stream = concat over rows i of
    ``nbr[start[i] : start[i] + deg[i]]``; each candidate v survives iff
    ``(bitmap[v] & label_mask) == label_mask`` and (when ``bound_id >= 0``)
    ``v == bound_id``.  Survivors are compacted to a prefix, preserving
    stream order.  Returns ``(v_out, row_out, count)`` each sized
    ``capacity`` / scalar: slot k < count holds surviving candidate
    ``v_out[k]`` produced by input row ``row_out[k]``; slots >= count are
    ``-1``.  Slots beyond ``capacity`` are dropped (the caller detects that
    via its own total-vs-capacity overflow check).
    """
    row, j, valid = ragged_expand_ref(offs, deg, capacity)
    idx = jnp.clip(start[row] + j, 0, max(1, nbr.shape[0]) - 1)
    v = jnp.where(valid, nbr[idx], -1)
    vsafe = jnp.clip(v, 0, bitmap.shape[0] - 1)
    ok = valid & bitmap_superset_ref(bitmap[vsafe], label_mask)
    ok &= (bound_id < 0) | (v == bound_id)
    pos = jnp.where(ok, jnp.cumsum(ok.astype(jnp.int32)) - 1, capacity)
    v_out = jnp.full((capacity + 1,), -1, jnp.int32).at[pos].set(v)[:capacity]
    row_out = jnp.full((capacity + 1,), -1, jnp.int32).at[pos].set(row)[:capacity]
    return v_out, row_out, jnp.sum(ok.astype(jnp.int32))


def delta_merge_ref(
    base_nbr: jax.Array,  # int32 [mb]  base CSR adjacency values
    delta_nbr: jax.Array,  # int32 [md] delta-insert adjacency values
    tomb_nbr: jax.Array,  # int32 [mt]  tombstoned base neighbors (sorted runs)
    b_start: jax.Array,  # int32 [K]   per-slot base slice start
    b_deg: jax.Array,  # int32 [K]     per-slot base slice length
    d_start: jax.Array,  # int32 [K]   per-slot delta slice start
    t_lo: jax.Array,  # int32 [K]      per-slot tombstone slice start
    t_hi: jax.Array,  # int32 [K]      per-slot tombstone slice end
    j: jax.Array,  # int32 [K]         within-row candidate position
    valid: jax.Array,  # bool [K]
    n_iters: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """Merged base+delta expansion slot resolution with tombstone masking.

    Slot ``k`` resolves within-row position ``j[k]`` of a logical adjacency
    list that is base slice ``base_nbr[b_start:b_start+b_deg)`` followed by
    the delta slice starting at ``d_start`` — positions ``j < b_deg`` read
    the base CSR, later positions read the delta CSR.  Base-sourced
    candidates found in the (sorted) tombstone slice ``tomb_nbr[t_lo:t_hi)``
    are masked out; delta candidates are never tombstoned (the store keeps
    inserts and tombstones disjoint).  Returns ``(v, ok)``: the candidate
    per slot (-1 when invalid) and its post-tombstone validity.
    """
    is_base = j < b_deg
    mb = max(1, base_nbr.shape[0])
    md = max(1, delta_nbr.shape[0])
    v_b = base_nbr[jnp.clip(b_start + j, 0, mb - 1)]
    v_d = delta_nbr[jnp.clip(d_start + (j - b_deg), 0, md - 1)]
    v = jnp.where(is_base, v_b, v_d)
    dead = is_base & edge_exists_ref(tomb_nbr, t_lo, t_hi, v,
                                     n_iters=n_iters)
    return jnp.where(valid, v, -1), valid & ~dead


def delta_merge_labeled_ref(
    base_nbr: jax.Array,  # int32 [mb] plain-CSR neighbors (all labels)
    base_lab: jax.Array,  # int32 [mb] edge label aligned with base_nbr
    delta_nbr: jax.Array,  # int32 [md]
    delta_lab: jax.Array,  # int32 [md]
    tomb_key: jax.Array,  # int32 [mt] sorted composite nbr*n_elabels+el runs
    b_start: jax.Array,
    b_deg: jax.Array,
    d_start: jax.Array,
    t_lo: jax.Array,
    t_hi: jax.Array,
    j: jax.Array,
    valid: jax.Array,
    n_elabels: int,
    n_iters: int = 32,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Predicate-variable variant of :func:`delta_merge_ref`: candidates
    carry their edge label, and tombstone probing matches the exact
    (neighbor, label) pair via the composite key ``nbr * n_elabels + el``.
    Returns ``(v, el, ok)``."""
    is_base = j < b_deg
    mb = max(1, base_nbr.shape[0])
    md = max(1, delta_nbr.shape[0])
    ib = jnp.clip(b_start + j, 0, mb - 1)
    idlt = jnp.clip(d_start + (j - b_deg), 0, md - 1)
    v = jnp.where(is_base, base_nbr[ib], delta_nbr[idlt])
    el = jnp.where(is_base, base_lab[ib], delta_lab[idlt])
    key = v * jnp.int32(n_elabels) + el
    dead = is_base & edge_exists_ref(tomb_key, t_lo, t_hi, key,
                                     n_iters=n_iters)
    ok = valid & ~dead
    return jnp.where(valid, v, -1), jnp.where(valid, el, -1), ok


def ragged_expand_ref(
    offsets: jax.Array,  # int32 [R] exclusive cumsum of per-row degrees
    degrees: jax.Array,  # int32 [R]
    capacity: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flatten ragged per-row ranges into output slots.

    Returns (row, j, valid) each [capacity]: slot k belongs to input row
    ``row[k]`` at within-row position ``j[k]``; slots beyond the total are
    invalid.  This is the executor's expansion primitive.
    """
    total = jnp.sum(degrees)
    k = jnp.arange(capacity, dtype=jnp.int32)
    row = jnp.searchsorted(offsets, k, side="right").astype(jnp.int32) - 1
    row = jnp.clip(row, 0, max(1, offsets.shape[0]) - 1)
    j = k - offsets[row]
    valid = (k < total) & (j < degrees[row]) & (j >= 0)
    return row, j, valid
