"""Cooperative cancellation.

A :class:`CancelToken` is created where a deadline is known (the
scheduler's ``submit``, or ``SparqlEngine.query(timeout_ms=...)``) and
threaded by reference down to the executor's chunk loop.  The executor
polls it at chunk boundaries and suffix-resume re-entries -- the
natural yield points of the freeze-at-overflow design -- so an expired
or abandoned flight stops dispatching within one chunk.

Deadlines are absolute ``time.monotonic()`` values, which makes the
token safe to extend when a coalescing scheduler attaches a second
waiter with a later deadline.
"""

from __future__ import annotations

import threading
import time


class QueryCancelled(RuntimeError):
    """A query was cancelled mid-execution (deadline or abandonment).

    ``partial_stats`` holds whatever execution stats had accumulated by
    the time the cancel was observed; the serve layer surfaces a
    compact subset in the HTTP 504 body.
    """

    def __init__(
        self,
        message: str = "query cancelled",
        *,
        partial_stats: dict | None = None,
        queue_wait_ms: float | None = None,
        exec_ms: float | None = None,
    ) -> None:
        super().__init__(message)
        self.partial_stats = partial_stats or {}
        self.queue_wait_ms = queue_wait_ms
        self.exec_ms = exec_ms


class CancelToken:
    """Thread-safe cancellation handle with an optional absolute deadline."""

    __slots__ = ("_lock", "deadline", "_cancelled", "_reason")

    def __init__(self, deadline: float | None = None) -> None:
        self._lock = threading.Lock()
        self.deadline = deadline  # absolute time.monotonic(), or None
        self._cancelled = False
        self._reason: str | None = None

    def cancel(self, reason: str = "cancelled") -> None:
        with self._lock:
            if not self._cancelled:
                self._cancelled = True
                self._reason = reason

    def extend(self, deadline: float | None) -> None:
        """Push the deadline later (never earlier); ``None`` clears it."""
        with self._lock:
            if deadline is None:
                self.deadline = None
            elif self.deadline is not None:
                self.deadline = max(self.deadline, deadline)

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    @property
    def reason(self) -> str | None:
        if self._cancelled:
            return self._reason
        if self.deadline is not None and time.monotonic() >= self.deadline:
            return "deadline exceeded"
        return None

    @property
    def expired(self) -> bool:
        if self._cancelled:
            return True
        d = self.deadline
        return d is not None and time.monotonic() >= d

    def remaining(self) -> float | None:
        """Seconds until the deadline (None if no deadline). <=0 if past."""
        d = self.deadline
        if d is None:
            return None
        return d - time.monotonic()

    def check(self, partial_stats: dict | None = None) -> None:
        """Raise :class:`QueryCancelled` if cancelled or past deadline."""
        if self.expired:
            raise QueryCancelled(
                f"query cancelled: {self.reason or 'cancelled'}",
                partial_stats=dict(partial_stats) if partial_stats else {},
            )
