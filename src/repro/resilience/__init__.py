"""Resilience primitives for the serve path.

Three pieces, designed to compose:

- :mod:`repro.resilience.cancel` -- cooperative cancellation.  A
  :class:`CancelToken` carries an absolute ``time.monotonic()`` deadline
  from ``scheduler.submit`` / ``?timeout_ms`` down into the executor's
  chunk loop; :class:`QueryCancelled` surfaces with partial stats.
- :mod:`repro.resilience.policy` -- transient-fault retry with bounded
  exponential backoff, a degradation ladder (smaller capacity schedule
  -> no fused kernel -> legacy executor), and a per-plan-signature
  breaker that remembers the working degraded config and re-probes a
  less-degraded level after a cooldown.
- :mod:`repro.resilience.faults` -- deterministic, seeded fault
  injection at named sites (compile, dispatch, delta_merge,
  store_commit) so chaos tests are reproducible.
"""

from repro.resilience.cancel import CancelToken, QueryCancelled
from repro.resilience.faults import FaultInjector, FaultSpec, InjectedFault, parse_fault_spec
from repro.resilience.policy import (
    MAX_LEVEL,
    DegradationBreaker,
    RetryPolicy,
    degrade_opts,
    is_transient_fault,
)

__all__ = [
    "CancelToken",
    "QueryCancelled",
    "FaultInjector",
    "FaultSpec",
    "InjectedFault",
    "parse_fault_spec",
    "RetryPolicy",
    "DegradationBreaker",
    "degrade_opts",
    "is_transient_fault",
    "MAX_LEVEL",
]
