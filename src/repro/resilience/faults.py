"""Deterministic fault injection for chaos testing.

Named sites are wired into the hot path (``fire(site)`` is a no-op
attribute check when no injector is installed):

- ``compile``       -- a fresh jit compile in ``Executor._get_fn``
- ``dispatch``      -- every chunk dispatch, suffix-resume re-entry,
                       profiled-chunk step, and batched dispatch
- ``delta_merge``   -- merging versioned-store delta CSRs into dense
                       arrays (``Executor._snapshot_arrays``)
- ``store_commit``  -- ``VersionedStore.apply_update`` after validation,
                       before mutation

Kinds:

- ``oom``           -- raises :class:`InjectedFault` whose message
                       contains ``RESOURCE_EXHAUSTED`` (the transient
                       policy treats it like a real device OOM)
- ``compile_error`` -- raises :class:`InjectedFault` (transient)
- ``latency``       -- sleeps ``latency_ms`` then continues
- ``poison``        -- returns True; the dispatch site corrupts the
                       chunk's result so end-to-end checks can detect
                       silent wrong answers

Specs are parsed from ``site:kind[:rate[:latency_ms]]`` strings joined
with ``;`` (env ``REPRO_FAULTS``, seeded by ``REPRO_FAULT_SEED``).
Each spec gets its own ``random.Random`` stream derived from
(seed, spec index), so a given (spec, seed) pair fires at the exact
same sequence of site visits on every run.
"""

from __future__ import annotations

import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, replace
from random import Random

SITES = ("compile", "dispatch", "delta_merge", "store_commit")
KINDS = ("oom", "compile_error", "latency", "poison")


class InjectedFault(RuntimeError):
    """A fault raised by the injection harness (never by real code)."""

    def __init__(self, site: str, kind: str, message: str | None = None) -> None:
        if message is None:
            message = f"injected {kind} at {site}"
            if kind == "oom":
                message += ": RESOURCE_EXHAUSTED (simulated out of memory)"
        super().__init__(message)
        self.site = site
        self.kind = kind


@dataclass(frozen=True)
class FaultSpec:
    site: str
    kind: str
    rate: float = 1.0  # probability of firing per site visit
    times: int | None = None  # stop firing after this many (None = unlimited)
    latency_ms: float = 0.0  # for kind == "latency"

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; expected one of {SITES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected one of {KINDS}")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"fault rate must be in [0, 1], got {self.rate}")


def parse_fault_spec(text: str) -> tuple[FaultSpec, ...]:
    """Parse ``"site:kind[:rate[:latency_ms]];..."`` into FaultSpecs."""
    specs: list[FaultSpec] = []
    for part in text.replace(",", ";").split(";"):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        if len(bits) < 2:
            raise ValueError(f"bad fault spec {part!r}; want site:kind[:rate[:latency_ms]]")
        site, kind = bits[0], bits[1]
        rate = float(bits[2]) if len(bits) > 2 else 1.0
        latency_ms = float(bits[3]) if len(bits) > 3 else 0.0
        specs.append(FaultSpec(site=site, kind=kind, rate=rate, latency_ms=latency_ms))
    return tuple(specs)


class FaultInjector:
    """Seeded injector; thread-safe; counts every fire per (site, kind)."""

    def __init__(self, specs, seed: int = 0) -> None:
        if isinstance(specs, str):
            specs = parse_fault_spec(specs)
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._rng = {i: Random(self.seed * 1_000_003 + i) for i in range(len(self.specs))}
        self._fired = {i: 0 for i in range(len(self.specs))}
        self.counters: dict[tuple[str, str], int] = {}
        self._by_site: dict[str, list[int]] = {}
        for i, s in enumerate(self.specs):
            self._by_site.setdefault(s.site, []).append(i)

    def fire(self, site: str) -> bool:
        """Visit a site. Raises/sleeps per matching specs; True => poison."""
        idxs = self._by_site.get(site)
        if not idxs:
            return False
        actions: list[FaultSpec] = []
        with self._lock:
            for i in idxs:
                spec = self.specs[i]
                if spec.times is not None and self._fired[i] >= spec.times:
                    continue
                if spec.rate < 1.0 and self._rng[i].random() >= spec.rate:
                    continue
                self._fired[i] += 1
                key = (spec.site, spec.kind)
                self.counters[key] = self.counters.get(key, 0) + 1
                actions.append(spec)
        poison = False
        for spec in actions:
            if spec.kind == "latency":
                if spec.latency_ms > 0:
                    time.sleep(spec.latency_ms / 1e3)
            elif spec.kind == "poison":
                poison = True
            else:  # oom | compile_error
                raise InjectedFault(site, spec.kind)
        return poison

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "seed": self.seed,
                "specs": [
                    f"{s.site}:{s.kind}:{s.rate}" + (f":{s.latency_ms}" if s.latency_ms else "")
                    for s in self.specs
                ],
                "fired": {f"{site}:{kind}": n for (site, kind), n in sorted(self.counters.items())},
            }


# ---------------------------------------------------------------------------
# Module-level active injector. ``fire`` is called from the executor hot
# path, so the inactive case must stay a couple of attribute loads.

_active: FaultInjector | None = None
_env_checked = False


def _load_env() -> None:
    global _active, _env_checked
    _env_checked = True
    spec = os.environ.get("REPRO_FAULTS", "").strip()
    if spec:
        seed = int(os.environ.get("REPRO_FAULT_SEED", "0"))
        _active = FaultInjector(parse_fault_spec(spec), seed=seed)


def active() -> FaultInjector | None:
    if not _env_checked:
        _load_env()
    return _active


def install(injector: FaultInjector | None) -> FaultInjector | None:
    """Install (or clear) the active injector; returns the previous one."""
    global _active, _env_checked
    prev = active()
    _active = injector
    _env_checked = True
    return prev


def fire(site: str) -> bool:
    inj = _active
    if inj is None:
        if _env_checked:
            return False
        inj = active()
        if inj is None:
            return False
    return inj.fire(site)


def describe() -> dict | None:
    inj = active()
    return inj.snapshot() if inj is not None else None


@contextmanager
def inject(spec, seed: int = 0, times: int | None = None):
    """Scoped injector for tests: ``with faults.inject("dispatch:oom", times=3):``."""
    specs = parse_fault_spec(spec) if isinstance(spec, str) else tuple(spec)
    if times is not None:
        specs = tuple(replace(s, times=times) for s in specs)
    inj = FaultInjector(specs, seed=seed)
    prev = install(inj)
    try:
        yield inj
    finally:
        install(prev)
