"""Transient-fault policy: retry, degradation ladder, breaker.

The executor wraps each top-level ``run`` in this policy.  A transient
fault (RESOURCE_EXHAUSTED / injected OOM / compile failure) is retried
a bounded number of times with exponential backoff at the current
degradation level; when retries are exhausted the run escalates one
ladder level and starts over from host inputs (runs are pure with
respect to their numpy inputs, so a re-run is safe):

- level 0: normal config
- level 1: halved chunk + halved capacity schedule (cap_slack * 0.5,
  init_cap / 2) + synchronous dispatch (async_chunks=1)
- level 2: level 1 + fused kernel disabled
- level 3: legacy executor (no capacity schedule, no suffix resume)

A per-plan-signature :class:`DegradationBreaker` remembers the level
that last worked so subsequent runs of the same plan skip the failing
configs, and re-probes one level lower after a cooldown -- the same
probe-and-remember shape as the executor's ``_small_plan`` machinery.
"""

from __future__ import annotations

import os
import threading
from dataclasses import dataclass, replace
from time import monotonic

from repro.resilience.faults import InjectedFault

MAX_LEVEL = 3

_TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "resource_exhausted", "out of memory", "OOM")


def is_transient_fault(exc: BaseException) -> bool:
    """True for faults worth retrying/degrading over (OOM-shaped)."""
    if isinstance(exc, InjectedFault):
        return exc.kind in ("oom", "compile_error")
    if isinstance(exc, MemoryError):
        return True
    text = str(exc)
    return any(m in text for m in _TRANSIENT_MARKERS)


def degrade_opts(opts, level: int):
    """Return a degraded copy of an ``ExecOpts`` for a ladder level.

    Works on any dataclass with the executor's option fields; imports
    nothing from ``repro.core`` to stay cycle-free.
    """
    if level <= 0:
        return opts
    if level >= MAX_LEVEL:
        return replace(
            opts,
            cap_schedule=False,
            suffix_resume=False,
            async_chunks=1,
            use_fused=False,
            chunk=max(512, opts.chunk // 2),
        )
    out = replace(
        opts,
        chunk=max(512, opts.chunk // 2),
        init_cap=max(1024, opts.init_cap // 2),
        cap_slack=opts.cap_slack * 0.5,
        async_chunks=1,
    )
    if level >= 2:
        out = replace(out, use_fused=False)
    return out


@dataclass(frozen=True)
class RetryPolicy:
    max_retries: int = 2  # same-level retries before escalating
    backoff_s: float = 0.005
    backoff_max_s: float = 0.25
    cooldown_s: float = 30.0  # breaker re-probe cooldown

    @classmethod
    def from_env(cls) -> "RetryPolicy":
        return cls(
            max_retries=int(os.environ.get("REPRO_RETRY_MAX", "2")),
            backoff_s=float(os.environ.get("REPRO_RETRY_BACKOFF_MS", "5")) / 1e3,
            cooldown_s=float(os.environ.get("REPRO_BREAKER_COOLDOWN_S", "30")),
        )

    def backoff(self, attempt: int) -> float:
        return min(self.backoff_s * (2**attempt), self.backoff_max_s)


class DegradationBreaker:
    """Per-plan-signature memory of the working degradation level."""

    def __init__(self, cooldown_s: float = 30.0) -> None:
        self.cooldown_s = cooldown_s
        self._lock = threading.Lock()
        # sig -> (level, probe_at): run at `level`; once monotonic() >=
        # probe_at, optimistically probe one level lower.
        self._state: dict[object, tuple[int, float]] = {}

    def level(self, sig, now: float | None = None) -> int:
        now = monotonic() if now is None else now
        with self._lock:
            ent = self._state.get(sig)
            if ent is None:
                return 0
            lvl, probe_at = ent
            if now >= probe_at:
                return max(0, lvl - 1)
            return lvl

    def record_failure(self, sig, level: int, now: float | None = None) -> int:
        """Escalate past a failed level; returns the next level to try."""
        now = monotonic() if now is None else now
        nxt = min(level + 1, MAX_LEVEL)
        with self._lock:
            self._state[sig] = (nxt, now + self.cooldown_s)
        return nxt

    def record_success(self, sig, level: int, now: float | None = None) -> None:
        now = monotonic() if now is None else now
        with self._lock:
            if level <= 0:
                self._state.pop(sig, None)
            else:
                self._state[sig] = (level, now + self.cooldown_s)

    def snapshot(self) -> dict:
        with self._lock:
            levels = [lvl for lvl, _ in self._state.values()]
            return {
                "degraded_plans": len(levels),
                "max_level": max(levels, default=0),
                "levels": {str(lv): levels.count(lv) for lv in sorted(set(levels))},
            }
