"""repro.stats — graph statistics shared by the cost-based planner.

``get_stats(graph)`` builds a :class:`GraphStats` once per
:class:`~repro.rdf.graph.LabeledGraph` and caches it on the graph object:
per-predicate cardinalities, per-direction fanout tables, label frequency /
cooccurrence, and a bounded-sample join-cardinality estimator.
"""

from repro.stats.graph_stats import GraphStats, get_stats, patch_stats

__all__ = ["GraphStats", "get_stats", "patch_stats"]
