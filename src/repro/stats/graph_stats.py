"""Unified graph statistics for the cost-based planner (paper §4.2).

``GraphStats`` is built once per :class:`~repro.rdf.graph.LabeledGraph` and
cached on it (``get_stats``).  It centralizes every number the planner used
to recompute inline on each ``build_plan`` call:

- per-predicate edge counts and distinct subject/object counts (the
  predicate index sizes, without materializing the index arrays);
- per-(predicate, direction) average and maximum fanout;
- vertex-label frequency (``freq(g, l)``) and a label-cooccurrence table
  giving exact two-label intersection sizes (multi-label frequencies fall
  back to the tightest pairwise bound, with an exact memoized path for the
  label sets queries actually mention);
- a bounded-sample join-cardinality estimator: given a sample of source
  vertices, the observed mean fanout under a (predicate, direction) — the
  paper's candidate-region-size estimation distilled to one probe.

Everything is derived from arrays the graph already holds; building is a
few vectorized passes over the per-label CSR offset tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.rdf.graph import LabeledGraph

# above this many vertex labels the dense cooccurrence table is skipped
# (planner falls back to min(label_freq) bounds + the exact memo)
_MAX_DENSE_COOC = 512
# default sample bound for sampled_fanout
_SAMPLE_BOUND = 256


@dataclass
class GraphStats:
    graph: LabeledGraph = field(repr=False)
    n_vertices: int
    n_edges: int
    n_elabels: int
    n_vlabels: int
    # per-predicate: edge count, distinct subjects/objects
    pred_edges: np.ndarray  # int64 [n_elabels]
    pred_subjects: np.ndarray  # int64 [n_elabels]
    pred_objects: np.ndarray  # int64 [n_elabels]
    # per-(predicate, direction) fanout
    fanout_avg_out: np.ndarray  # float64 [n_elabels]
    fanout_avg_in: np.ndarray
    fanout_max_out: np.ndarray  # int64 [n_elabels]
    fanout_max_in: np.ndarray
    # vertex-label tables
    label_freq: np.ndarray  # int64 [n_vlabels]
    label_cooc: np.ndarray | None  # int64 [n_vlabels, n_vlabels] or None
    avg_degree: float
    # memoized exact multi-label frequencies (small: only label sets that
    # queries mention)
    _freq_memo: dict[tuple[int, ...], int] = field(default_factory=dict,
                                                   repr=False)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(g: LabeledGraph) -> "GraphStats":
        if g.n_elabels:
            deg_out = np.diff(g.out.indptr_el, axis=1)  # [n_elabels, n_vertices]
            deg_in = np.diff(g.inc.indptr_el, axis=1)
            pred_edges = deg_out.sum(axis=1)
            pred_subjects = (deg_out > 0).sum(axis=1)
            pred_objects = (deg_in > 0).sum(axis=1)
            fanout_avg_out = pred_edges / np.maximum(1, pred_subjects)
            fanout_avg_in = pred_edges / np.maximum(1, pred_objects)
            fanout_max_out = deg_out.max(axis=1, initial=0)
            fanout_max_in = deg_in.max(axis=1, initial=0)
        else:
            z = np.zeros(0, np.int64)
            pred_edges = pred_subjects = pred_objects = z
            fanout_avg_out = fanout_avg_in = np.zeros(0, np.float64)
            fanout_max_out = fanout_max_in = z
        label_freq = (np.diff(g.vl_indptr).astype(np.int64)[: g.n_vlabels]
                      if g.n_vlabels else np.zeros(0, np.int64))
        label_cooc = None
        if 0 < g.n_vlabels <= _MAX_DENSE_COOC:
            # chunked M^T M over the unpacked label bitmap: vectorized, and
            # peak extra memory stays at chunk x n_vlabels float32
            cooc = np.zeros((g.n_vlabels, g.n_vlabels), dtype=np.float64)
            chunk = 1 << 16
            for lo in range(0, g.n_vertices, chunk):
                words = g.label_bitmap[lo : lo + chunk]
                bits = np.unpackbits(
                    words.view(np.uint8), axis=1, bitorder="little"
                )[:, : g.n_vlabels].astype(np.float32)
                cooc += bits.T @ bits
            label_cooc = cooc.astype(np.int64)
        return GraphStats(
            graph=g,
            n_vertices=g.n_vertices,
            n_edges=g.n_edges,
            n_elabels=g.n_elabels,
            n_vlabels=g.n_vlabels,
            pred_edges=pred_edges,
            pred_subjects=pred_subjects,
            pred_objects=pred_objects,
            fanout_avg_out=fanout_avg_out,
            fanout_avg_in=fanout_avg_in,
            fanout_max_out=fanout_max_out,
            fanout_max_in=fanout_max_in,
            label_freq=label_freq,
            label_cooc=label_cooc,
            avg_degree=float(g.out.degree.mean()) if g.n_vertices else 0.0,
        )

    # ------------------------------------------------------------- predicates
    def avg_fanout(self, el: int, forward: bool) -> float:
        """Mean out-degree of subjects (forward) / in-degree of objects."""
        if el < 0 or el >= self.n_elabels:
            return self.avg_degree + 1.0
        return float((self.fanout_avg_out if forward
                      else self.fanout_avg_in)[el])

    def max_fanout(self, el: int, forward: bool) -> int:
        if el < 0 or el >= self.n_elabels:
            return self.n_vertices
        return int((self.fanout_max_out if forward
                    else self.fanout_max_in)[el])

    def pred_sources(self, el: int, forward: bool) -> int:
        """Distinct subjects (forward) / objects (backward) of predicate el."""
        if el < 0 or el >= self.n_elabels:
            return self.n_vertices
        return int((self.pred_subjects if forward else self.pred_objects)[el])

    # ----------------------------------------------------------- label tables
    def freq(self, labels: Sequence[int]) -> int:
        """|∩_l V_l| — exact for 0/1/2 labels, exact-memoized beyond."""
        labels = tuple(sorted(labels))
        if not labels:
            return self.n_vertices
        if len(labels) == 1:
            return int(self.label_freq[labels[0]])
        if len(labels) == 2 and self.label_cooc is not None:
            return int(self.label_cooc[labels[0], labels[1]])
        hit = self._freq_memo.get(labels)
        if hit is None:
            hit = self.graph.freq(list(labels))
            self._freq_memo[labels] = hit
        return hit

    def label_selectivity(self, labels: Sequence[int]) -> float:
        if not labels:
            return 1.0
        return max(1.0, float(self.freq(labels))) / max(1, self.n_vertices)

    # ----------------------------------------------- sampled join cardinality
    def sampled_fanout(self, el: int, forward: bool,
                       sources: np.ndarray,
                       bound: int = _SAMPLE_BOUND) -> float:
        """Bounded-sample join-cardinality estimate: mean (el, direction)
        fanout over at most ``bound`` of the given source vertices.  This is
        the planner's probe for "how many rows does expanding this edge from
        *these* candidates produce", vs. the whole-graph average.

        Sources beyond the stats' vertex space (snapshot-born vertices when
        planning against a live store) are dropped from the sample — the
        estimate stays an estimate, never an IndexError."""
        if sources.size == 0:
            return 0.0
        sample = sources[:bound].astype(np.int64)
        sample = sample[sample < self.graph.n_vertices]
        if sample.size == 0:
            return self.avg_fanout(el, forward)
        d = self.graph.out if forward else self.graph.inc
        if el < 0 or el >= self.n_elabels:
            return float(d.degree[sample].mean())
        degs = d.indptr_el[el, sample + 1] - d.indptr_el[el, sample]
        return float(degs.mean())

    def snapshot(self) -> dict:
        """Small JSON-able summary (diagnostics / /healthz)."""
        return {
            "n_vertices": self.n_vertices,
            "n_edges": self.n_edges,
            "n_elabels": self.n_elabels,
            "n_vlabels": self.n_vlabels,
            "avg_degree": round(self.avg_degree, 3),
            "max_fanout_out": int(self.fanout_max_out.max(initial=0)),
            "max_fanout_in": int(self.fanout_max_in.max(initial=0)),
        }


def get_stats(g) -> GraphStats:
    """Return the graph's cached ``GraphStats``, building it on first use.

    The cache lives on the graph object itself, so a graph rebuilt in place
    (new object) naturally gets fresh statistics.  A live-store
    :class:`~repro.store.versioned.Snapshot` resolves to its *base* graph's
    stats: planner estimates tolerate the (small, bounded-by-compaction)
    drift, and every correctness-relevant quantity — candidate sets,
    predicate indexes — is answered exactly by the snapshot itself.
    """
    if getattr(g, "is_snapshot", False):
        return get_stats(g.base)
    s = getattr(g, "_graph_stats", None)
    if s is None or s.graph is not g:
        s = GraphStats.build(g)
        g._graph_stats = s  # type: ignore[attr-defined]
    return s


# --------------------------------------------------------------------------
# incremental maintenance (store compaction)
# --------------------------------------------------------------------------


def _affected_pairs(ins: np.ndarray, tombs: np.ndarray,
                    col: int) -> tuple[np.ndarray, np.ndarray]:
    """Distinct (el, vertex) pairs touched by the delta, with the vertex
    taken from COO column ``col`` (0 = subjects, 2 = objects)."""
    parts = [a[:, (1, col)] for a in (ins, tombs) if a.shape[0]]
    if not parts:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    pairs = np.unique(np.concatenate(parts), axis=0)
    return pairs[:, 0], pairs[:, 1]


def _el_deg(g: LabeledGraph, els: np.ndarray, vs: np.ndarray,
            forward: bool) -> np.ndarray:
    d = g.out if forward else g.inc
    deg = np.zeros(els.shape[0], dtype=np.int64)
    ok = (els < g.n_elabels) & (vs < g.n_vertices)
    if ok.any():
        deg[ok] = (d.indptr_el[els[ok], vs[ok] + 1]
                   - d.indptr_el[els[ok], vs[ok]])
    return deg


def patch_stats(old: GraphStats, new_g: LabeledGraph, *, ins: np.ndarray,
                tombs: np.ndarray,
                label_changes: list[tuple[int, tuple, tuple]]) -> GraphStats:
    """Exact incremental ``GraphStats`` maintenance across a compaction.

    ``ins`` / ``tombs`` are the folded delta as int64 COO ``[k, 3]`` arrays
    of (src, el, dst) rows; ``label_changes`` lists ``(vertex, old_labels,
    new_labels)`` for every vertex whose label set changed (new vertices
    have ``old_labels == ()``).  Instead of the full O(n_elabels × V) diff
    passes and the O(V × L²) cooccurrence rebuild of
    :meth:`GraphStats.build`, only the touched (predicate, vertex) pairs
    and changed label sets are visited; the result is bit-identical to a
    from-scratch build (asserted by the store test suite).
    """
    old_g = old.graph
    n_el = new_g.n_elabels

    def extend(a: np.ndarray, fill=0) -> np.ndarray:
        if a.shape[0] >= n_el:
            return a.astype(np.int64).copy()
        return np.concatenate(
            [a.astype(np.int64), np.full(n_el - a.shape[0], fill, np.int64)])

    pred_edges = extend(old.pred_edges)
    if ins.shape[0]:
        pred_edges += np.bincount(ins[:, 1], minlength=n_el)
    if tombs.shape[0]:
        pred_edges -= np.bincount(tombs[:, 1], minlength=n_el)

    counts = {}
    maxes = {}
    for name, col, forward in (("pred_subjects", 0, True),
                               ("pred_objects", 2, False)):
        side = extend(getattr(old, name))
        els, vs = _affected_pairs(ins, tombs, col)
        old_deg = _el_deg(old_g, els, vs, forward)
        new_deg = _el_deg(new_g, els, vs, forward)
        became = ((old_deg == 0) & (new_deg > 0)).astype(np.int64)
        died = ((old_deg > 0) & (new_deg == 0)).astype(np.int64)
        if els.size:
            side += np.bincount(els, weights=became,
                                minlength=n_el).astype(np.int64)
            side -= np.bincount(els, weights=died,
                                minlength=n_el).astype(np.int64)
        counts[name] = side
        # per-el max fanout: grows to max(old, touched new degs); a delete
        # that may have clipped the old max forces one O(V) row recompute
        fmax = extend(getattr(old, "fanout_max_out" if forward
                              else "fanout_max_in"))
        if els.size:
            for e in np.unique(els):
                m = els == e
                cand = int(new_deg[m].max(initial=0))
                lowered = bool(((old_deg[m] == fmax[e])
                                & (new_deg[m] < old_deg[m])).any())
                if lowered:
                    d = new_g.out if forward else new_g.inc
                    fmax[e] = int(np.diff(d.indptr_el[e]).max(initial=0))
                else:
                    fmax[e] = max(int(fmax[e]), cand)
        maxes["out" if forward else "in"] = fmax

    label_freq = old.label_freq.astype(np.int64).copy()
    label_cooc = None if old.label_cooc is None else \
        old.label_cooc.astype(np.int64).copy()
    for _vid, old_ls, new_ls in label_changes:
        for ls, sign in ((old_ls, -1), (new_ls, 1)):
            if not ls:
                continue
            arr = np.asarray(ls, dtype=np.int64)
            label_freq[arr] += sign
            if label_cooc is not None:
                label_cooc[np.ix_(arr, arr)] += sign

    with np.errstate(divide="ignore", invalid="ignore"):
        fanout_avg_out = pred_edges / np.maximum(1, counts["pred_subjects"])
        fanout_avg_in = pred_edges / np.maximum(1, counts["pred_objects"])
    return GraphStats(
        graph=new_g,
        n_vertices=new_g.n_vertices,
        n_edges=new_g.n_edges,
        n_elabels=n_el,
        n_vlabels=new_g.n_vlabels,
        pred_edges=pred_edges,
        pred_subjects=counts["pred_subjects"],
        pred_objects=counts["pred_objects"],
        fanout_avg_out=fanout_avg_out,
        fanout_avg_in=fanout_avg_in,
        fanout_max_out=maxes["out"],
        fanout_max_in=maxes["in"],
        label_freq=label_freq,
        label_cooc=label_cooc,
        avg_degree=float(new_g.out.degree.mean()) if new_g.n_vertices else 0.0,
    )
