"""Unified graph statistics for the cost-based planner (paper §4.2).

``GraphStats`` is built once per :class:`~repro.rdf.graph.LabeledGraph` and
cached on it (``get_stats``).  It centralizes every number the planner used
to recompute inline on each ``build_plan`` call:

- per-predicate edge counts and distinct subject/object counts (the
  predicate index sizes, without materializing the index arrays);
- per-(predicate, direction) average and maximum fanout;
- vertex-label frequency (``freq(g, l)``) and a label-cooccurrence table
  giving exact two-label intersection sizes (multi-label frequencies fall
  back to the tightest pairwise bound, with an exact memoized path for the
  label sets queries actually mention);
- a bounded-sample join-cardinality estimator: given a sample of source
  vertices, the observed mean fanout under a (predicate, direction) — the
  paper's candidate-region-size estimation distilled to one probe.

Everything is derived from arrays the graph already holds; building is a
few vectorized passes over the per-label CSR offset tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.rdf.graph import LabeledGraph

# above this many vertex labels the dense cooccurrence table is skipped
# (planner falls back to min(label_freq) bounds + the exact memo)
_MAX_DENSE_COOC = 512
# default sample bound for sampled_fanout
_SAMPLE_BOUND = 256


@dataclass
class GraphStats:
    graph: LabeledGraph = field(repr=False)
    n_vertices: int
    n_edges: int
    n_elabels: int
    n_vlabels: int
    # per-predicate: edge count, distinct subjects/objects
    pred_edges: np.ndarray  # int64 [n_elabels]
    pred_subjects: np.ndarray  # int64 [n_elabels]
    pred_objects: np.ndarray  # int64 [n_elabels]
    # per-(predicate, direction) fanout
    fanout_avg_out: np.ndarray  # float64 [n_elabels]
    fanout_avg_in: np.ndarray
    fanout_max_out: np.ndarray  # int64 [n_elabels]
    fanout_max_in: np.ndarray
    # vertex-label tables
    label_freq: np.ndarray  # int64 [n_vlabels]
    label_cooc: np.ndarray | None  # int64 [n_vlabels, n_vlabels] or None
    avg_degree: float
    # memoized exact multi-label frequencies (small: only label sets that
    # queries mention)
    _freq_memo: dict[tuple[int, ...], int] = field(default_factory=dict,
                                                   repr=False)

    # ------------------------------------------------------------------ build
    @staticmethod
    def build(g: LabeledGraph) -> "GraphStats":
        if g.n_elabels:
            deg_out = np.diff(g.out.indptr_el, axis=1)  # [n_elabels, n_vertices]
            deg_in = np.diff(g.inc.indptr_el, axis=1)
            pred_edges = deg_out.sum(axis=1)
            pred_subjects = (deg_out > 0).sum(axis=1)
            pred_objects = (deg_in > 0).sum(axis=1)
            fanout_avg_out = pred_edges / np.maximum(1, pred_subjects)
            fanout_avg_in = pred_edges / np.maximum(1, pred_objects)
            fanout_max_out = deg_out.max(axis=1, initial=0)
            fanout_max_in = deg_in.max(axis=1, initial=0)
        else:
            z = np.zeros(0, np.int64)
            pred_edges = pred_subjects = pred_objects = z
            fanout_avg_out = fanout_avg_in = np.zeros(0, np.float64)
            fanout_max_out = fanout_max_in = z
        label_freq = (np.diff(g.vl_indptr).astype(np.int64)[: g.n_vlabels]
                      if g.n_vlabels else np.zeros(0, np.int64))
        label_cooc = None
        if 0 < g.n_vlabels <= _MAX_DENSE_COOC:
            # chunked M^T M over the unpacked label bitmap: vectorized, and
            # peak extra memory stays at chunk x n_vlabels float32
            cooc = np.zeros((g.n_vlabels, g.n_vlabels), dtype=np.float64)
            chunk = 1 << 16
            for lo in range(0, g.n_vertices, chunk):
                words = g.label_bitmap[lo : lo + chunk]
                bits = np.unpackbits(
                    words.view(np.uint8), axis=1, bitorder="little"
                )[:, : g.n_vlabels].astype(np.float32)
                cooc += bits.T @ bits
            label_cooc = cooc.astype(np.int64)
        return GraphStats(
            graph=g,
            n_vertices=g.n_vertices,
            n_edges=g.n_edges,
            n_elabels=g.n_elabels,
            n_vlabels=g.n_vlabels,
            pred_edges=pred_edges,
            pred_subjects=pred_subjects,
            pred_objects=pred_objects,
            fanout_avg_out=fanout_avg_out,
            fanout_avg_in=fanout_avg_in,
            fanout_max_out=fanout_max_out,
            fanout_max_in=fanout_max_in,
            label_freq=label_freq,
            label_cooc=label_cooc,
            avg_degree=float(g.out.degree.mean()) if g.n_vertices else 0.0,
        )

    # ------------------------------------------------------------- predicates
    def avg_fanout(self, el: int, forward: bool) -> float:
        """Mean out-degree of subjects (forward) / in-degree of objects."""
        if el < 0 or el >= self.n_elabels:
            return self.avg_degree + 1.0
        return float((self.fanout_avg_out if forward
                      else self.fanout_avg_in)[el])

    def max_fanout(self, el: int, forward: bool) -> int:
        if el < 0 or el >= self.n_elabels:
            return self.n_vertices
        return int((self.fanout_max_out if forward
                    else self.fanout_max_in)[el])

    def pred_sources(self, el: int, forward: bool) -> int:
        """Distinct subjects (forward) / objects (backward) of predicate el."""
        if el < 0 or el >= self.n_elabels:
            return self.n_vertices
        return int((self.pred_subjects if forward else self.pred_objects)[el])

    # ----------------------------------------------------------- label tables
    def freq(self, labels: Sequence[int]) -> int:
        """|∩_l V_l| — exact for 0/1/2 labels, exact-memoized beyond."""
        labels = tuple(sorted(labels))
        if not labels:
            return self.n_vertices
        if len(labels) == 1:
            return int(self.label_freq[labels[0]])
        if len(labels) == 2 and self.label_cooc is not None:
            return int(self.label_cooc[labels[0], labels[1]])
        hit = self._freq_memo.get(labels)
        if hit is None:
            hit = self.graph.freq(list(labels))
            self._freq_memo[labels] = hit
        return hit

    def label_selectivity(self, labels: Sequence[int]) -> float:
        if not labels:
            return 1.0
        return max(1.0, float(self.freq(labels))) / max(1, self.n_vertices)

    # ----------------------------------------------- sampled join cardinality
    def sampled_fanout(self, el: int, forward: bool,
                       sources: np.ndarray,
                       bound: int = _SAMPLE_BOUND) -> float:
        """Bounded-sample join-cardinality estimate: mean (el, direction)
        fanout over at most ``bound`` of the given source vertices.  This is
        the planner's probe for "how many rows does expanding this edge from
        *these* candidates produce", vs. the whole-graph average."""
        if sources.size == 0:
            return 0.0
        if el < 0 or el >= self.n_elabels:
            d = self.graph.out if forward else self.graph.inc
            sample = sources[:bound].astype(np.int64)
            return float(d.degree[sample].mean())
        d = self.graph.out if forward else self.graph.inc
        sample = sources[:bound].astype(np.int64)
        degs = d.indptr_el[el, sample + 1] - d.indptr_el[el, sample]
        return float(degs.mean())

    def snapshot(self) -> dict:
        """Small JSON-able summary (diagnostics / /healthz)."""
        return {
            "n_vertices": self.n_vertices,
            "n_edges": self.n_edges,
            "n_elabels": self.n_elabels,
            "n_vlabels": self.n_vlabels,
            "avg_degree": round(self.avg_degree, 3),
            "max_fanout_out": int(self.fanout_max_out.max(initial=0)),
            "max_fanout_in": int(self.fanout_max_in.max(initial=0)),
        }


def get_stats(g: LabeledGraph) -> GraphStats:
    """Return the graph's cached ``GraphStats``, building it on first use.

    The cache lives on the graph object itself, so a graph rebuilt in place
    (new object) naturally gets fresh statistics.
    """
    s = getattr(g, "_graph_stats", None)
    if s is None or s.graph is not g:
        s = GraphStats.build(g)
        g._graph_stats = s  # type: ignore[attr-defined]
    return s
