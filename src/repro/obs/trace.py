"""Allocation-light nested span traces for one query execution.

A :class:`Trace` is owned by a single logical request.  Code on the query
path receives ``trace=None`` by default and guards every annotation with
``if trace is not None`` — the disabled path costs one pointer compare.
Spans form a tree; timestamps are seconds relative to the trace origin
(``time.perf_counter`` based, so only durations and intra-trace offsets
are meaningful).

Span tree construction is stack-based: ``with trace.span("execute"): ...``
nests everything opened inside under it.  Spans may also be attached
post-hoc with a known duration (``trace.add``) — the executor uses that to
report per-step device wall times measured by its profiled path — or as
zero-duration events (``trace.event``).

A trace is *not* generally thread-safe; the serving layer hands it from
the submitting thread to the scheduler worker sequentially (parse spans
finish before the flight is enqueued), which is safe.
"""

from __future__ import annotations

import contextlib
import itertools
import json
import time
from typing import Any, Iterator

_ids = itertools.count(1)


class Span:
    """One node of the span tree.  ``t0``/``dur`` are seconds relative to
    the owning trace's origin."""

    __slots__ = ("name", "t0", "dur", "meta", "children")

    def __init__(self, name: str, t0: float, meta: dict | None = None):
        self.name = name
        self.t0 = t0
        self.dur = 0.0
        self.meta: dict[str, Any] = meta if meta is not None else {}
        self.children: list[Span] = []

    def to_dict(self) -> dict:
        d: dict[str, Any] = {"name": self.name,
                             "t0_ms": round(self.t0 * 1e3, 4),
                             "dur_ms": round(self.dur * 1e3, 4)}
        if self.meta:
            d["meta"] = self.meta
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, t0={self.t0 * 1e3:.3f}ms, "
                f"dur={self.dur * 1e3:.3f}ms, {len(self.children)} children)")


class Trace:
    """A single request's span tree.

    ``profile_steps=True`` marks a *forced* trace: the engine executes in
    profiled mode (per-step host syncs) so step spans carry real device
    wall times and the span sum accounts for end-to-end wall time.
    Sampled traces keep the fast execution path and report per-step
    counters with zero-duration step spans instead.
    """

    __slots__ = ("trace_id", "name", "origin", "root", "_stack",
                 "profile_steps", "sampled", "query_id", "dataset", "thread")

    def __init__(self, name: str = "query", *, profile_steps: bool = False,
                 sampled: bool = False):
        self.trace_id = next(_ids)
        self.name = name
        self.origin = time.perf_counter()
        self.root = Span(name, 0.0)
        self._stack: list[Span] = [self.root]
        self.profile_steps = profile_steps
        self.sampled = sampled
        # correlation labels, filled by the serving layer: the scheduler's
        # query_id, the dataset served, and the worker thread that ran it
        self.query_id: str | None = None
        self.dataset: str | None = None
        self.thread: str | None = None

    # ------------------------------------------------------------ recording
    def _now(self) -> float:
        return time.perf_counter() - self.origin

    @contextlib.contextmanager
    def span(self, name: str, **meta: Any) -> Iterator[Span]:
        s = Span(name, self._now(), meta or None)
        parent = self._stack[-1]
        parent.children.append(s)
        self._stack.append(s)
        try:
            yield s
        finally:
            s.dur = self._now() - s.t0
            self._stack.pop()

    def add(self, name: str, dur_s: float = 0.0, **meta: Any) -> Span:
        """Attach a completed span (known duration) under the current one."""
        s = Span(name, self._now() - dur_s, meta or None)
        s.dur = dur_s
        self._stack[-1].children.append(s)
        return s

    def event(self, name: str, **meta: Any) -> Span:
        """Zero-duration marker (plan-cache hit, compile detection, ...)."""
        return self.add(name, 0.0, **meta)

    def finish(self) -> "Trace":
        """Close the root span; safe to call more than once."""
        self.root.dur = self._now()
        del self._stack[1:]
        return self

    # ----------------------------------------------------------- inspection
    @property
    def dur_ms(self) -> float:
        return self.root.dur * 1e3

    def span_sum_ms(self) -> float:
        """Sum of top-level child durations — the accounted-for share of
        the end-to-end wall time."""
        return sum(c.dur for c in self.root.children) * 1e3

    def find(self, name: str) -> list[Span]:
        out: list[Span] = []

        def walk(s: Span) -> None:
            if s.name == name:
                out.append(s)
            for c in s.children:
                walk(c)

        walk(self.root)
        return out

    def to_dict(self) -> dict:
        d = {"id": self.trace_id,
             "sampled": self.sampled,
             "profiled": self.profile_steps,
             "dur_ms": round(self.dur_ms, 4),
             "span_sum_ms": round(self.span_sum_ms(), 4),
             "root": self.root.to_dict()}
        if self.query_id is not None:
            d["query_id"] = self.query_id
        if self.dataset is not None:
            d["dataset"] = self.dataset
        if self.thread is not None:
            d["thread"] = self.thread
        return d


def _chrome_events(span: Span, pid: int, tid: int, out: list[dict]) -> None:
    args = {k: (v if isinstance(v, (int, float, str, bool, type(None)))
                else repr(v))
            for k, v in (span.meta or {}).items()}
    out.append({"name": span.name, "ph": "X", "pid": pid, "tid": tid,
                "ts": round(span.t0 * 1e6, 3),
                "dur": round(span.dur * 1e6, 3), "args": args})
    for c in span.children:
        _chrome_events(c, pid, tid, out)


def chrome_trace(traces: "Trace | list[Trace]", as_text: bool = False):
    """Render one or more traces as Chrome ``trace_event`` JSON (load in
    chrome://tracing or https://ui.perfetto.dev).

    Traces are grouped into one process lane per dataset (``Trace.dataset``;
    unlabeled traces share the default ``repro`` process) with
    ``process_name`` / ``thread_name`` metadata events, so Perfetto shows
    dataset and worker-thread names instead of bare pids/tids.  Each trace
    is its own thread lane, labeled with the worker thread that ran it
    (when the serving layer recorded one) plus the trace id / query id.
    """
    if isinstance(traces, Trace):
        traces = [traces]
    events: list[dict] = []
    meta: list[dict] = []
    pids: dict[str | None, int] = {}
    for tid, t in enumerate(traces, start=1):
        ds = t.dataset
        pid = pids.get(ds)
        if pid is None:
            pid = pids[ds] = len(pids) + 1
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0,
                         "args": {"name": f"dataset:{ds}" if ds else "repro"}})
        label = t.thread or t.name
        suffix = t.query_id or f"#{t.trace_id}"
        meta.append({"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                     "args": {"name": f"{label} {suffix}"}})
        _chrome_events(t.root, pid, tid, events)
    doc = {"traceEvents": meta + events, "displayTimeUnit": "ms"}
    return json.dumps(doc) if as_text else doc
