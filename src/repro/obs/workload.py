"""Workload intelligence: q-error accounting + decision journal.

Aggregates *across* queries what :mod:`repro.obs.trace` records for one:
every completed execution folds its ``Result.stats`` into a bounded
per-``(dataset, plan_key)`` :class:`WorkloadProfile` — per-step
observed-vs-estimated cardinality accounting (q-error), kernel mix,
prune ratios, suffix-resume/retry counts, batch-lane fill, degradation
levels — while a :class:`DecisionJournal` ring buffer records each
engine choice (plan-cache hit/miss, small-plan probe, batch coalesce,
prune, breaker level, cancellation) with its inputs.

The profiler also closes the loop: when a profile's median worst-step
q-error exceeds ``qerror_threshold`` over the last ``min_runs`` runs,
:meth:`WorkloadProfiler.observe` returns a *replan hint* carrying the
observed per-edge fanouts, keyed ``(child, parent, elabel, forward)``
over stable query-vertex indices so they survive an order-search re-run
(the caller feeds them to ``SparqlEngine.apply_feedback``, which marks
the cached plan stale; see ``core/planner/cost.py``).  Feedback is
bounded (``max_replans`` per profile), versioned, and purely an
estimator override — results stay bit-identical as multisets.

Everything here is host-side bookkeeping on numbers the executor
already produces; nothing touches the jitted path.
"""

from __future__ import annotations

import itertools
import math
import threading
import time
from collections import Counter, OrderedDict, deque

__all__ = [
    "qerror",
    "qerror_log10",
    "WorkloadProfile",
    "WorkloadProfiler",
    "DecisionJournal",
]

# observed fanouts are clamped into this range before they reach the
# cost model — a pathological run must not poison planning forever
_FANOUT_MIN = 1e-4
_FANOUT_MAX = 1e6


def qerror(estimated: float, actual: float) -> float:
    """Symmetric relative cardinality error, >= 1.0 (1.0 = exact).

    Both sides are +1-smoothed so empty results don't divide by zero;
    ``log10(qerror(e, a))`` equals the absolute log-ratio the
    ``repro_cardinality_error_log10`` metrics have always recorded.
    """
    e = max(0.0, float(estimated)) + 1.0
    a = max(0.0, float(actual)) + 1.0
    return max(e / a, a / e)


def qerror_log10(estimated: float, actual: float) -> float:
    return math.log10(qerror(estimated, actual))


def _median(vals) -> float:
    s = sorted(vals)
    if not s:
        return 0.0
    n = len(s)
    mid = n // 2
    return float(s[mid]) if n % 2 else float((s[mid - 1] + s[mid]) / 2.0)


class DecisionJournal:
    """Bounded ring buffer of engine decisions with their inputs.

    Entries are plain dicts ``{"seq", "t", "kind", ...fields}`` — newest
    first in :meth:`snapshot`.  ``record`` is cheap enough for the hot
    path (one deque append under a lock); readers get copies.
    """

    def __init__(self, size: int = 512):
        self._buf: deque[dict] = deque(maxlen=max(1, int(size)))
        self._lock = threading.Lock()
        self._seq = itertools.count(1)
        self.counts: Counter[str] = Counter()

    def record(self, kind: str, **fields) -> None:
        entry = {"seq": next(self._seq), "t": time.time(), "kind": kind}
        entry.update(fields)
        with self._lock:
            self._buf.append(entry)
            self.counts[kind] += 1

    def snapshot(self, limit: int | None = None,
                 kind: str | None = None) -> list[dict]:
        with self._lock:
            entries = list(self._buf)
        entries.reverse()  # newest first
        if kind is not None:
            entries = [e for e in entries if e["kind"] == kind]
        if limit is not None:
            entries = entries[: max(0, int(limit))]
        return [dict(e) for e in entries]

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)


class WorkloadProfile:
    """Aggregated execution statistics for one (dataset, plan_key).

    Per-step aggregates use ratio-of-sums (``sum_kept / sum_in``) so one
    tiny run cannot dominate the observed fanout, plus a bounded deque
    of recent per-run q-errors for the median-based replan trigger.
    Step-level state resets when the plan signature changes (a replan or
    live-store drift re-ordered the steps); run counters are cumulative.
    """

    def __init__(self, dataset: str, plan_key: str, window: int = 32):
        self.dataset = dataset
        self.plan_key = plan_key
        self.window = max(2, int(window))
        self.runs = 0
        self.wall_ms_total = 0.0
        self.last_wall_ms = 0.0
        self.rows_total = 0
        self.kernels: Counter[str] = Counter()
        self.degraded: Counter[int] = Counter()
        self.resumes = 0
        self.compiles = 0
        self.retries = 0
        self.batched_runs = 0
        self.batch_fill_sum = 0.0
        self.cancels = 0
        self.replans = 0
        self.feedback_version = 0
        self.runs_since_replan = 0
        self.fingerprint: str | None = None
        self.search: str | None = None
        # per-run q-error deques (worst step / end-to-end)
        self.run_qerrs: deque[float] = deque(maxlen=self.window)
        self.e2e_qerrs: deque[float] = deque(maxlen=self.window)
        self._sig: int | None = None
        self._reset_steps(0)

    def _reset_steps(self, n: int) -> None:
        self.n_steps = n
        self.est_rows: list[float] = [0.0] * n
        self.sum_in = [0] * n
        self.sum_kept = [0] * n
        self.sum_expanded = [0] * n
        self.sum_prune_in = [0] * n
        self.sum_prune_out = [0] * n
        self.sum_retries = [0] * n
        self.step_qerrs: list[deque[float]] = [
            deque(maxlen=self.window) for _ in range(n)]
        # (child, parent, elabel, forward) per step; -1 parent = restart
        self.step_edges: list[tuple[int, int, int, bool] | None] = [None] * n

    # -- folding -----------------------------------------------------------

    def fold(self, plan, stats: dict, *, count: int, wall_ms: float,
             fingerprint: str | None = None) -> None:
        """Fold one completed run.  ``plan`` is the branch-0 base
        ``ExecPlan`` (duck-typed: est_rows / steps / start_candidates /
        signature / search); ``stats`` its base ``Result.stats``."""
        sig = hash(plan.signature())
        if sig != self._sig:
            self._sig = sig
            self._reset_steps(len(plan.steps))
            self.est_rows = [float(x) for x in plan.est_rows][: self.n_steps]
            for i, s in enumerate(plan.steps[: self.n_steps]):
                self.step_edges[i] = (int(s.u), int(s.parent),
                                      int(s.elabel), bool(s.forward))
        if fingerprint is not None:
            self.fingerprint = fingerprint
        self.search = getattr(plan, "search", None)
        self.runs += 1
        self.runs_since_replan += 1
        self.wall_ms_total += float(wall_ms)
        self.last_wall_ms = float(wall_ms)
        self.rows_total += int(count)

        kept = [int(x) for x in (stats.get("step_kept") or [])]
        expanded = [int(x) for x in (stats.get("step_rows") or [])]
        retries = [int(x) for x in (stats.get("step_retries") or [])]
        p_in = [int(x) for x in (stats.get("step_prune_in") or [])]
        p_out = [int(x) for x in (stats.get("step_prune_out") or [])]
        try:
            n0 = int(plan.start_candidates.shape[0])
        except AttributeError:
            n0 = 0

        worst = 1.0
        inputs = n0
        for i in range(min(self.n_steps, len(kept))):
            self.sum_in[i] += inputs
            self.sum_kept[i] += kept[i]
            if i < len(expanded):
                self.sum_expanded[i] += expanded[i]
            if i < len(retries):
                self.sum_retries[i] += retries[i]
                self.retries += retries[i]
            if i < len(p_in) and p_in[i] >= 0:
                self.sum_prune_in[i] += p_in[i]
                self.sum_prune_out[i] += max(0, p_out[i])
            if i < len(self.est_rows):
                qe = qerror(self.est_rows[i], kept[i])
                self.step_qerrs[i].append(qe)
                worst = max(worst, qe)
            inputs = kept[i]
        self.run_qerrs.append(worst)
        est_total = self.est_rows[-1] if self.est_rows else float(max(1, n0))
        self.e2e_qerrs.append(qerror(est_total, count))

        for k in stats.get("step_kernels") or []:
            self.kernels[str(k)] += 1
        self.degraded[int(stats.get("degraded_level") or 0)] += 1
        self.resumes += int(stats.get("resumes") or 0)
        self.compiles += int(stats.get("compiles") or 0)
        if stats.get("batched"):
            self.batched_runs += 1
            self.batch_fill_sum += float(stats.get("batch_fill") or 1.0)

    # -- derived -----------------------------------------------------------

    def median_qerror(self, last: int | None = None) -> float:
        vals = list(self.run_qerrs)
        if last is not None:
            vals = vals[-last:]
        return _median(vals) if vals else 1.0

    def observed_fanouts(self) -> dict[tuple[int, int, int, bool],
                                       tuple[float, float]]:
        """Per-edge observed (surviving, raw-expansion) fanouts, keyed by
        ``(child, parent, elabel, forward)`` query-vertex indices.
        Restart steps (parent == -1) and never-fed steps are skipped."""
        out: dict[tuple[int, int, int, bool], tuple[float, float]] = {}
        for i in range(self.n_steps):
            edge = self.step_edges[i]
            if edge is None or edge[1] < 0 or self.sum_in[i] <= 0:
                continue
            card = self.sum_kept[i] / self.sum_in[i]
            raw = self.sum_expanded[i] / self.sum_in[i]
            clamp = lambda v: min(_FANOUT_MAX, max(_FANOUT_MIN, v))  # noqa: E731
            out[edge] = (clamp(card), clamp(max(raw, card)))
        return out

    def snapshot(self) -> dict:
        steps = []
        for i in range(self.n_steps):
            rec = {
                "est_rows": self.est_rows[i] if i < len(self.est_rows) else None,
                "obs_rows": (self.sum_kept[i] / self.runs) if self.runs else 0.0,
                "q_error_median": _median(self.step_qerrs[i])
                if self.step_qerrs[i] else None,
                "retries": self.sum_retries[i],
            }
            if self.sum_in[i] > 0:
                rec["obs_fanout"] = self.sum_kept[i] / self.sum_in[i]
            if self.sum_prune_in[i] > 0:
                rec["prune_ratio"] = 1.0 - (self.sum_prune_out[i]
                                            / self.sum_prune_in[i])
            steps.append(rec)
        return {
            "dataset": self.dataset,
            "plan_key": self.plan_key,
            "fingerprint": self.fingerprint,
            "search": self.search,
            "runs": self.runs,
            "rows_total": self.rows_total,
            "wall_ms_total": self.wall_ms_total,
            "last_wall_ms": self.last_wall_ms,
            "q_error_median": self.median_qerror(),
            "q_error_max": max(self.run_qerrs) if self.run_qerrs else 1.0,
            "e2e_q_error_median": _median(self.e2e_qerrs)
            if self.e2e_qerrs else 1.0,
            "kernels": dict(self.kernels),
            "degraded": {str(k): v for k, v in sorted(self.degraded.items())},
            "resumes": self.resumes,
            "compiles": self.compiles,
            "retries": self.retries,
            "batched_runs": self.batched_runs,
            "batch_fill_avg": (self.batch_fill_sum / self.batched_runs)
            if self.batched_runs else None,
            "cancels": self.cancels,
            "replans": self.replans,
            "feedback_version": self.feedback_version,
            "steps": steps,
        }


class WorkloadProfiler:
    """Bounded LRU of :class:`WorkloadProfile` + replan trigger.

    ``observe`` folds one run and returns either ``None`` or a replan
    hint ``{"fingerprint", "fanouts", "q_error_median", "version"}``
    when feedback is enabled and the profile has been consistently
    misestimated.  The profiler never mutates the engine itself — the
    caller owns applying the hint (and journaling it), which keeps this
    module import-free of :mod:`repro.core`.
    """

    def __init__(self, *, max_profiles: int = 256, window: int = 32,
                 feedback: bool = False, qerror_threshold: float = 8.0,
                 min_runs: int = 5, max_replans: int = 3,
                 journal: DecisionJournal | None = None):
        self.max_profiles = max(1, int(max_profiles))
        self.window = int(window)
        self.feedback = bool(feedback)
        self.qerror_threshold = float(qerror_threshold)
        self.min_runs = max(1, int(min_runs))
        self.max_replans = max(0, int(max_replans))
        self.journal = journal
        self._profiles: OrderedDict[tuple[str, str], WorkloadProfile] = \
            OrderedDict()
        self._lock = threading.Lock()
        self.evictions = 0

    def _get(self, dataset: str, plan_key: str) -> WorkloadProfile:
        key = (dataset, plan_key)
        prof = self._profiles.get(key)
        if prof is None:
            prof = WorkloadProfile(dataset, plan_key, window=self.window)
            self._profiles[key] = prof
            while len(self._profiles) > self.max_profiles:
                self._profiles.popitem(last=False)
                self.evictions += 1
        else:
            self._profiles.move_to_end(key)
        return prof

    def observe(self, dataset: str, plan_key: str, plan, stats: dict, *,
                count: int, wall_ms: float,
                fingerprint: str | None = None) -> dict | None:
        with self._lock:
            prof = self._get(dataset, plan_key)
            prof.fold(plan, stats, count=count, wall_ms=wall_ms,
                      fingerprint=fingerprint)
            if not self.feedback or prof.fingerprint is None:
                return None
            if (prof.replans >= self.max_replans
                    or prof.runs_since_replan < self.min_runs
                    or len(prof.run_qerrs) < self.min_runs):
                return None
            med = prof.median_qerror(last=self.min_runs)
            if med <= self.qerror_threshold:
                return None
            fanouts = prof.observed_fanouts()
            if not fanouts:
                return None
            prof.replans += 1
            prof.feedback_version += 1
            prof.runs_since_replan = 0
            prof.run_qerrs.clear()
            for dq in prof.step_qerrs:
                dq.clear()
            return {"fingerprint": prof.fingerprint, "fanouts": fanouts,
                    "q_error_median": med, "version": prof.feedback_version,
                    "dataset": dataset, "plan_key": plan_key}

    def record_cancel(self, dataset: str, plan_key: str) -> None:
        with self._lock:
            if (dataset, plan_key) in self._profiles:
                self._profiles[(dataset, plan_key)].cancels += 1

    def snapshot(self, limit: int | None = None) -> list[dict]:
        with self._lock:
            profs = list(self._profiles.values())
        out = [p.snapshot() for p in profs]
        out.sort(key=lambda d: (d["q_error_median"], d["runs"]), reverse=True)
        if limit is not None:
            out = out[: max(0, int(limit))]
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._profiles)
