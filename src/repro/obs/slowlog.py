"""Bounded in-memory slow-query log: the N worst traces, one per query
fingerprint.

Recording is O(capacity) with a plain scan for the eviction victim —
capacities are tens of entries, so a heap would only add bookkeeping.
Entries carry the finished trace, the annotated (EXPLAIN ANALYZE style)
plan description, and enough identity to re-run the query.
"""

from __future__ import annotations

import threading
import time
from typing import Any

from repro.obs.trace import Trace, chrome_trace


class SlowQueryLog:
    """Keep the ``capacity`` slowest traces seen, keyed by fingerprint.

    A repeated fingerprint keeps its single worst observation (the log
    answers "which *queries* are slow", not "which executions"), and a new
    fingerprint evicts the current fastest entry once the log is full —
    only if the newcomer is slower.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self._by_fp: dict[str, dict] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._by_fp)

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def record(self, fingerprint: str, wall_ms: float, trace: Trace,
               **extra: Any) -> bool:
        """Offer one finished execution; returns True if it was kept."""
        if not self.enabled:
            return False
        entry = {"id": trace.trace_id, "fingerprint": fingerprint,
                 "wall_ms": round(float(wall_ms), 3),
                 "recorded_at": time.time(), "trace": trace, **extra}
        with self._lock:
            prev = self._by_fp.get(fingerprint)
            if prev is not None:
                if wall_ms <= prev["wall_ms"]:
                    return False
                self._by_fp[fingerprint] = entry
                return True
            if len(self._by_fp) >= self.capacity:
                fastest = min(self._by_fp.values(),
                              key=lambda e: e["wall_ms"])
                if wall_ms <= fastest["wall_ms"]:
                    return False
                del self._by_fp[fastest["fingerprint"]]
            self._by_fp[fingerprint] = entry
            return True

    def get(self, trace_id: int) -> dict | None:
        with self._lock:
            for e in self._by_fp.values():
                if e["id"] == trace_id:
                    return e
        return None

    def entries(self) -> list[dict]:
        """All entries, slowest first."""
        with self._lock:
            items = list(self._by_fp.values())
        return sorted(items, key=lambda e: -e["wall_ms"])

    def summaries(self) -> list[dict]:
        """JSON-able digest, slowest first (no span trees)."""
        out = []
        for e in self.entries():
            out.append({k: v for k, v in e.items()
                        if k not in ("trace", "explain")})
        return out

    @staticmethod
    def render_entry(entry: dict, fmt: str = "json") -> dict:
        """Full JSON view of one entry; ``fmt="chrome"`` swaps the span
        tree for Chrome trace_event JSON."""
        trace: Trace = entry["trace"]
        out = {k: v for k, v in entry.items() if k != "trace"}
        if fmt == "chrome":
            return chrome_trace(trace)
        out["trace"] = trace.to_dict()
        return out

    def clear(self) -> None:
        with self._lock:
            self._by_fp.clear()
