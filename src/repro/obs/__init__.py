"""Structured tracing for the query path (PR: end-to-end observability).

``Trace`` collects a tree of spans — parse → fingerprint → plan →
compile → per-chunk dispatch → per-step kernel — cheaply enough to stay
in the serving hot path (off by default, sampled or forced per request).
``SlowQueryLog`` keeps the N worst traces per dataset for the
``/debug/slow`` endpoint; ``chrome_trace`` renders a trace as Chrome's
``trace_event`` JSON for one-click flamegraph viewing.

``repro.obs.workload`` aggregates *across* queries: per-plan-shape
``WorkloadProfile`` q-error accounting, a ``DecisionJournal`` of engine
choices, and the observed-fanout feedback loop into the planner; the
offline ``python -m repro.obs.report`` CLI merges profiles, slow-log
entries, and bench traces into one report.
"""

from repro.obs.slowlog import SlowQueryLog
from repro.obs.trace import Span, Trace, chrome_trace
from repro.obs.workload import (DecisionJournal, WorkloadProfile,
                                WorkloadProfiler, qerror, qerror_log10)

__all__ = ["Span", "Trace", "SlowQueryLog", "chrome_trace",
           "WorkloadProfile", "WorkloadProfiler", "DecisionJournal",
           "qerror", "qerror_log10"]
