"""Structured tracing for the query path (PR: end-to-end observability).

``Trace`` collects a tree of spans — parse → fingerprint → plan →
compile → per-chunk dispatch → per-step kernel — cheaply enough to stay
in the serving hot path (off by default, sampled or forced per request).
``SlowQueryLog`` keeps the N worst traces per dataset for the
``/debug/slow`` endpoint; ``chrome_trace`` renders a trace as Chrome's
``trace_event`` JSON for one-click flamegraph viewing.
"""

from repro.obs.slowlog import SlowQueryLog
from repro.obs.trace import Span, Trace, chrome_trace

__all__ = ["Span", "Trace", "SlowQueryLog", "chrome_trace"]
