"""Offline workload report: merge profiles, slow-log, and bench traces.

``python -m repro.obs.report`` turns the observability surfaces this
package accumulates at runtime into one reviewable document:

- ``--workload FILE`` — the ``GET /debug/workload`` payload (or a bare
  list of :meth:`WorkloadProfile.snapshot` dicts): worst-misestimated
  shapes, prune wins, kernel mix, degradation and replan history;
- ``--slow FILE`` — the ``GET /debug/slow`` payload: slowest traced
  executions per dataset;
- ``--bench-csv FILE`` — ``benchmarks.run`` CSV output (``name,
  us_per_call,derived``): slowest benchmark entries;
- ``--trace FILE`` — Chrome ``trace_event`` JSON (``--trace-out`` /
  ``/debug/trace?format=chrome``): where the wall time went, by span;
- ``--demo`` — build a small in-process LUBM+BSBM registry, drive the
  standard query mix through the scheduler with feedback enabled, and
  report on that (no files needed; used by ``examples/trace_query.py``).

``--format md`` (default) renders GitHub-flavored markdown; ``--format
json`` emits the merged report object.  ``--out FILE`` writes instead of
printing.  CI generates this report from the quick bench run and uploads
it next to the bench trace artifact.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import sys

__all__ = ["build_report", "render_markdown", "demo_report", "main"]


# --------------------------------------------------------------- loaders
def _load_json(path: str):
    with open(path) as f:
        return json.load(f)


def _norm_workload(obj) -> dict:
    """Accept the /debug/workload payload or a bare profile list."""
    if isinstance(obj, list):
        return {"profiles": obj, "feedback": {}, "decisions": {}}
    return {"profiles": obj.get("profiles", []),
            "feedback": obj.get("feedback", {}),
            "decisions": obj.get("decisions", {}),
            "feedback_enabled": obj.get("feedback_enabled")}


def _norm_slow(obj) -> dict:
    """Accept the /debug/slow payload ({"slow": {ds: [...]}}) or the bare
    per-dataset mapping."""
    if isinstance(obj, dict) and isinstance(obj.get("slow"), dict):
        return obj["slow"]
    return obj if isinstance(obj, dict) else {}


def _load_bench_csv(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            parts = line.strip().split(",")
            if len(parts) < 2 or parts[0] in ("", "name"):
                continue
            try:
                us = float(parts[1])
            except ValueError:
                continue
            rows.append({"name": parts[0], "us_per_call": us,
                         "derived": ",".join(parts[2:]).strip()})
    return rows


# -------------------------------------------------------------- sections
def _misestimated(profiles: list[dict], limit: int = 10) -> list[dict]:
    ranked = sorted(profiles, key=lambda p: p.get("q_error_median", 1.0),
                    reverse=True)
    return [{
        "dataset": p["dataset"], "plan_key": p["plan_key"],
        "runs": p["runs"], "q_error_median": round(p["q_error_median"], 2),
        "q_error_max": round(p.get("q_error_max", 1.0), 2),
        "e2e_q_error_median": round(p.get("e2e_q_error_median", 1.0), 2),
        "replans": p.get("replans", 0),
        "feedback_version": p.get("feedback_version", 0),
        "search": p.get("search"),
    } for p in ranked[:limit] if p.get("q_error_median", 1.0) > 1.0]


def _prune_wins(profiles: list[dict], limit: int = 10) -> list[dict]:
    wins = []
    for p in profiles:
        for i, s in enumerate(p.get("steps", ())):
            ratio = s.get("prune_ratio")
            if ratio:
                wins.append({"dataset": p["dataset"],
                             "plan_key": p["plan_key"], "step": i,
                             "prune_ratio": round(ratio, 3),
                             "runs": p["runs"]})
    wins.sort(key=lambda w: w["prune_ratio"] * w["runs"], reverse=True)
    return wins[:limit]


def _kernel_mix(profiles: list[dict]) -> dict[str, int]:
    mix: dict[str, int] = {}
    for p in profiles:
        for k, v in (p.get("kernels") or {}).items():
            mix[k] = mix.get(k, 0) + int(v)
    return dict(sorted(mix.items(), key=lambda kv: -kv[1]))


def _degradations(profiles: list[dict]) -> list[dict]:
    out = []
    for p in profiles:
        levels = {k: v for k, v in (p.get("degraded") or {}).items()
                  if k not in ("0", 0) and v}
        if levels or p.get("cancels"):
            out.append({"dataset": p["dataset"], "plan_key": p["plan_key"],
                        "degraded_runs": levels,
                        "cancels": p.get("cancels", 0),
                        "retries": p.get("retries", 0)})
    return out


def _replans(profiles: list[dict], feedback: dict) -> dict:
    return {
        "replanned_profiles": [
            {"dataset": p["dataset"], "plan_key": p["plan_key"],
             "replans": p["replans"],
             "feedback_version": p.get("feedback_version", 0),
             "search": p.get("search")}
            for p in profiles if p.get("replans")],
        "engine_feedback": feedback,
    }


def _trace_summary(trace_doc: dict, limit: int = 15) -> list[dict]:
    """Top spans by duration from Chrome trace_event JSON."""
    events = trace_doc.get("traceEvents", []) if isinstance(trace_doc, dict) \
        else []
    spans = [e for e in events if e.get("ph") == "X"]
    spans.sort(key=lambda e: -e.get("dur", 0.0))
    return [{"name": e.get("name"), "ms": round(e.get("dur", 0.0) / 1e3, 3)}
            for e in spans[:limit]]


# --------------------------------------------------------------- builder
def build_report(workload: dict | list | None = None,
                 slow: dict | None = None,
                 bench: list[dict] | None = None,
                 trace: dict | None = None) -> dict:
    """Merge the loaded surfaces into one JSON-able report object."""
    report: dict = {}
    if workload is not None:
        wl = _norm_workload(workload)
        profiles = wl["profiles"]
        report["workload"] = {
            "n_profiles": len(profiles),
            "feedback_enabled": wl.get("feedback_enabled"),
            "decisions": wl.get("decisions", {}),
            "misestimated": _misestimated(profiles),
            "prune_wins": _prune_wins(profiles),
            "kernel_mix": _kernel_mix(profiles),
            "degradations": _degradations(profiles),
            "replans": _replans(profiles, wl.get("feedback", {})),
        }
    if slow is not None:
        entries = [{"dataset": ds, **{k: v for k, v in e.items()
                                      if k in ("fingerprint", "wall_ms",
                                               "count", "id")}}
                   for ds, items in _norm_slow(slow).items()
                   for e in items]
        entries.sort(key=lambda e: -e.get("wall_ms", 0.0))
        report["slow_queries"] = entries[:15]
    if bench is not None:
        timed = [r for r in bench if not r["name"].startswith("_meta")]
        timed.sort(key=lambda r: -r["us_per_call"])
        meta = {r["name"]: r for r in bench if r["name"].startswith("_meta")}
        report["bench"] = {
            "n_entries": len(timed),
            "slowest": timed[:15],
            "total_seconds": round(
                meta["_meta.total_seconds"]["us_per_call"] / 1e6, 1)
            if "_meta.total_seconds" in meta else None,
        }
    if trace is not None:
        report["trace_spans"] = _trace_summary(trace)
    return report


# -------------------------------------------------------------- markdown
def _md_table(rows: list[dict], cols: list[str]) -> list[str]:
    if not rows:
        return ["*(none)*", ""]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    out.append("")
    return out


def render_markdown(report: dict) -> str:
    lines = ["# Workload report", ""]
    wl = report.get("workload")
    if wl:
        lines += [f"## Workload profiles ({wl['n_profiles']})", ""]
        if wl.get("decisions"):
            lines += ["Decisions: " + ", ".join(
                f"{k}={v}" for k, v in sorted(wl["decisions"].items())), ""]
        lines += ["### Top misestimated shapes", ""]
        lines += _md_table(wl["misestimated"],
                           ["dataset", "plan_key", "runs", "q_error_median",
                            "q_error_max", "replans", "search"])
        lines += ["### Top prune wins", ""]
        lines += _md_table(wl["prune_wins"],
                           ["dataset", "plan_key", "step", "prune_ratio",
                            "runs"])
        if wl.get("kernel_mix"):
            lines += ["### Kernel mix", ""]
            lines += _md_table([{"kernel": k, "runs": v}
                                for k, v in wl["kernel_mix"].items()],
                               ["kernel", "runs"])
        if wl.get("degradations"):
            lines += ["### Degradations / cancellations", ""]
            lines += _md_table(wl["degradations"],
                               ["dataset", "plan_key", "degraded_runs",
                                "cancels", "retries"])
        rp = wl.get("replans", {})
        if rp.get("replanned_profiles"):
            lines += ["### Feedback replans", ""]
            lines += _md_table(rp["replanned_profiles"],
                               ["dataset", "plan_key", "replans",
                                "feedback_version", "search"])
    if report.get("slow_queries") is not None:
        lines += ["## Slow queries", ""]
        lines += _md_table(report["slow_queries"],
                           ["dataset", "fingerprint", "wall_ms", "count"])
    bench = report.get("bench")
    if bench:
        total = (f" (total {bench['total_seconds']}s)"
                 if bench.get("total_seconds") else "")
        lines += [f"## Bench summary: {bench['n_entries']} entries{total}",
                  ""]
        lines += _md_table(bench["slowest"],
                           ["name", "us_per_call", "derived"])
    if report.get("trace_spans"):
        lines += ["## Trace: slowest spans", ""]
        lines += _md_table(report["trace_spans"], ["name", "ms"])
    return "\n".join(lines)


# ------------------------------------------------------------------ demo
def demo_report(rounds: int = 4) -> dict:
    """Build a small LUBM+BSBM registry, drive the standard query mix
    through the scheduler with feedback enabled, and report on it."""
    from repro.rdf.generator import generate_bsbm, generate_lubm
    from repro.rdf.transform import type_aware_transform
    from repro.rdf.workloads import BSBM_QUERIES, LUBM_QUERIES
    from repro.serve.scheduler import Scheduler
    from repro.serve.server import DatasetRegistry

    registry = DatasetRegistry(feedback=True, feedback_min_runs=3,
                               qerror_threshold=4.0, trace_sample=1.0)
    for name, store, queries in (
            ("lubm", generate_lubm(scale=1, density=0.5), LUBM_QUERIES),
            ("bsbm", generate_bsbm(n_products=200), BSBM_QUERIES)):
        store.finalize()
        g, maps = type_aware_transform(store)
        registry.register(name, g, maps)
    workloads = {"lubm": LUBM_QUERIES, "bsbm": BSBM_QUERIES}
    scheduler = Scheduler(registry, workers=2,
                          metrics=registry.metrics).start()
    try:
        for _ in range(max(1, rounds)):
            for ds, queries in workloads.items():
                for q in queries.values():
                    with contextlib.suppress(Exception):
                        scheduler.submit(ds, q, timeout_s=120.0)
    finally:
        scheduler.stop()
    return build_report(workload=registry.workload_snapshot(limit=None),
                        slow=registry.slow_summaries())


# ------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Merge workload profiles, slow-log, and bench traces "
                    "into one markdown/JSON report.")
    ap.add_argument("--workload", metavar="FILE",
                    help="GET /debug/workload JSON (or bare profile list)")
    ap.add_argument("--slow", metavar="FILE", help="GET /debug/slow JSON")
    ap.add_argument("--bench-csv", metavar="FILE",
                    help="benchmarks.run CSV output")
    ap.add_argument("--trace", metavar="FILE",
                    help="Chrome trace_event JSON (--trace-out)")
    ap.add_argument("--demo", action="store_true",
                    help="run a small in-process LUBM+BSBM workload with "
                         "feedback enabled and report on it")
    ap.add_argument("--format", choices=("md", "json"), default="md")
    ap.add_argument("--out", metavar="FILE", help="write instead of print")
    args = ap.parse_args(argv)

    if args.demo:
        report = demo_report()
        if args.bench_csv:
            report.update(build_report(bench=_load_bench_csv(args.bench_csv)))
        if args.trace:
            report.update(build_report(trace=_load_json(args.trace)))
    else:
        if not any((args.workload, args.slow, args.bench_csv, args.trace)):
            ap.error("nothing to report on: pass --workload/--slow/"
                     "--bench-csv/--trace or --demo")
        report = build_report(
            workload=_load_json(args.workload) if args.workload else None,
            slow=_load_json(args.slow) if args.slow else None,
            bench=_load_bench_csv(args.bench_csv) if args.bench_csv else None,
            trace=_load_json(args.trace) if args.trace else None)

    text = (json.dumps(report, indent=2, default=str)
            if args.format == "json" else render_markdown(report))
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        try:
            print(text)
        except BrokenPipeError:  # e.g. `report ... | head`
            sys.stderr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
