"""meshgraphnet [arXiv:2010.03409]: 15 message-passing layers, hidden 128,
sum aggregation, 2-layer MLPs with LayerNorm; dynamics regression."""

import dataclasses

from repro.configs.gnn_common import gnn_archdef
from repro.models.gnn import meshgraphnet as mgn

CONFIG = mgn.MGNConfig(
    name="meshgraphnet", n_layers=15, d_hidden=128, d_node_in=1433,
    d_edge_in=4, d_out=3, mlp_layers=2)

SMALL = dataclasses.replace(CONFIG, n_layers=3, d_hidden=16, d_node_in=12)

ARCH = gnn_archdef("meshgraphnet", CONFIG, mgn.loss_fn, SMALL,
                   notes="encode-process-decode mesh GNN [arXiv:2010.03409]; "
                         "d_node_in follows the active shape cell")
