"""Architecture registry plumbing.

Each config module defines an ``ArchDef``: the exact published configuration,
its assigned input-shape cells, ShapeDtypeStruct input specs for the dry-run,
and a reduced smoke configuration + real batch for CPU tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Cell:
    name: str
    kind: str  # "train" | "prefill" | "decode" | "serve" | "retrieval"
    meta: dict = field(default_factory=dict)


@dataclass
class ArchDef:
    name: str
    family: str  # "lm" | "gnn" | "recsys" | "engine"
    config: Any
    cells: dict[str, Cell]
    # (cell_name) -> batch pytree of ShapeDtypeStruct
    input_specs: Callable[[str], dict]
    # () -> (small_cfg, small_batch_of_real_arrays)
    smoke: Callable[[], tuple[Any, dict]]
    loss_fn: Callable | None = None
    notes: str = ""
    # per-cell config override (e.g. GNN d_feat follows the shape cell)
    cell_config: Callable[[str], Any] | None = None

    def config_for(self, cell_name: str):
        if self.cell_config is not None:
            return self.cell_config(cell_name)
        return self.config

    def abstract_params(self, init_fn):
        return jax.eval_shape(lambda k: init_fn(k, self.config),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# shared shape tables (from the assignment)
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

GNN_SHAPES = {
    "full_graph_sm": dict(n=2708, e=10556, d_feat=1433, kind="train",
                          regime="full"),
    "minibatch_lg": dict(n_full=232965, e_full=114615892, batch_nodes=1024,
                         fanout=(15, 10), d_feat=602, kind="train",
                         regime="sampled"),
    "ogb_products": dict(n=2449029, e=61859140, d_feat=100, kind="train",
                         regime="full"),
    "molecule": dict(n_per=30, e_per=64, batch=128, d_feat=16, kind="train",
                     regime="batched"),
}

RECSYS_SHAPES = {
    "train_batch": dict(batch=65536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1000000, kind="retrieval"),
}


def sampled_block_dims(batch_nodes: int, fanout) -> tuple[int, int]:
    """(n_sub, e_sub) for a padded layered-fanout block."""
    n = batch_nodes
    layer = batch_nodes
    e = 0
    for f in fanout:
        layer = layer * f
        n += layer
        e += layer
    return n, e


def lm_input_specs(cfg, cell_name: str) -> dict:
    from repro.models.transformer import init_cache

    s = LM_SHAPES[cell_name]
    if s["kind"] == "train":
        return {"tokens": sds((s["batch"], s["seq"])),
                "labels": sds((s["batch"], s["seq"]))}
    if s["kind"] == "prefill":
        return {"tokens": sds((s["batch"], s["seq"]))}
    # decode: 1 new token against a seq-length cache
    cache = jax.eval_shape(lambda: init_cache(cfg, s["batch"], s["seq"]))
    return {"tokens": sds((s["batch"], 1)), "cache": cache}


def gnn_input_specs(arch: str, cfg, cell_name: str) -> dict:
    s = GNN_SHAPES[cell_name]
    if s["regime"] == "sampled":
        n, e = sampled_block_dims(s["batch_nodes"], s["fanout"])
        d_feat = s["d_feat"]
        n_graphs = 1
    elif s["regime"] == "batched":
        n = s["n_per"] * s["batch"]
        e = s["e_per"] * s["batch"]
        d_feat = s["d_feat"]
        n_graphs = s["batch"]
    else:
        n, e, d_feat = s["n"], s["e"], s["d_feat"]
        n_graphs = 1
    base = {"edge_src": sds((e,)), "edge_dst": sds((e,))}
    if arch == "dimenet":
        t = 8 * e  # capped triplet budget (DimeNet++-style)
        base.update({
            "z": sds((n,)),
            "pos": sds((n, 3), jnp.float32),
            "t_kj": sds((t,)),
            "t_ji": sds((t,)),
            "batch_seg": sds((n,)),
            "targets": sds((n_graphs,), jnp.float32),
        })
    elif arch == "meshgraphnet":
        base.update({
            "x": sds((n, d_feat), jnp.float32),
            "edge_attr": sds((e, 4), jnp.float32),
            "targets": sds((n, 3), jnp.float32),
        })
    else:  # gcn / pna: node classification
        base.update({
            "x": sds((n, d_feat), jnp.float32),
            "labels": sds((n,)),
            "train_mask": sds((n,), jnp.bool_),
        })
    return base


def recsys_input_specs(cfg, cell_name: str) -> dict:
    s = RECSYS_SHAPES[cell_name]
    b = s["batch"]
    base = {
        "dense": sds((b, cfg.n_dense), jnp.float32),
        "sparse": sds((b, cfg.n_sparse, cfg.hotness)),
    }
    if s["kind"] == "train":
        base["labels"] = sds((b,), jnp.float32)
    if s["kind"] == "retrieval":
        base["cand"] = sds((s["n_candidates"], cfg.embed_dim), jnp.float32)
    return base
