"""pna [arXiv:2004.05718]: 4 layers, hidden 75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation."""

import dataclasses

from repro.configs.gnn_common import gnn_archdef
from repro.models.gnn import pna

CONFIG = pna.PNAConfig(
    name="pna", n_layers=4, d_hidden=75, d_feat=1433, n_classes=16)

SMALL = dataclasses.replace(CONFIG, d_hidden=16, d_feat=12, n_classes=4)

ARCH = gnn_archdef("pna", CONFIG, pna.loss_fn, SMALL,
                   notes="multi-aggregator (4 agg × 3 scalers) "
                         "[arXiv:2004.05718]")
