"""minitron-8b [arXiv:2407.14679]: 32L d_model=4096 32H (GQA kv=8)
d_ff=16384 vocab=256000 — width-pruned Nemotron-4."""

from repro.configs.lm_common import lm_archdef
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="minitron-8b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=256000,
    qk_norm=False,
    qkv_bias=False,
    rope_theta=1e4,
)

ARCH = lm_archdef(CONFIG, notes="pruned nemotron dense GQA [arXiv:2407.14679]")
