"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse features, embed_dim 64,
bottom MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction.

Vocab sizes: Criteo-like mixed magnitudes (the paper's RM-2 uses production
tables; these sum to ~19M rows)."""

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.common import RECSYS_SHAPES, ArchDef, Cell, recsys_input_specs
from repro.models.recsys import dlrm

VOCABS = (10_000_000, 4_000_000, 2_000_000, 1_500_000, 800_000, 400_000,
          200_000, 100_000, 50_000, 20_000, 10_000, 10_000, 5_000, 5_000,
          2_000, 2_000, 1_000, 1_000, 500, 500, 200, 200, 100, 100, 50, 50)

CONFIG = dlrm.DLRMConfig(
    name="dlrm-rm2",
    n_dense=13,
    n_sparse=26,
    embed_dim=64,
    bot_mlp=(512, 256, 64),
    top_mlp=(512, 256, 1),
    vocab_sizes=VOCABS,
    hotness=8,
)

SMALL = dataclasses.replace(
    CONFIG, vocab_sizes=tuple([64] * 26), bot_mlp=(32, 16), top_mlp=(32, 1),
    embed_dim=16, hotness=3)


def _smoke():
    rng = np.random.default_rng(0)
    b = 8
    batch = {
        "dense": jnp.asarray(rng.normal(size=(b, SMALL.n_dense)), jnp.float32),
        "sparse": jnp.asarray(
            rng.integers(-1, 64, (b, SMALL.n_sparse, SMALL.hotness)),
            jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 2, b), jnp.float32),
    }
    return SMALL, batch


ARCH = ArchDef(
    name="dlrm-rm2",
    family="recsys",
    config=CONFIG,
    cells={name: Cell(name, meta["kind"], dict(meta))
           for name, meta in RECSYS_SHAPES.items()},
    input_specs=lambda cell: recsys_input_specs(CONFIG, cell),
    smoke=_smoke,
    loss_fn=dlrm.loss_fn,
    notes="EmbeddingBag = take + masked segment sum (no native op in JAX); "
          "retrieval_cand scores 1M candidates with one GEMV",
)
