"""dbrx-132b [hf:databricks/dbrx-base]: 40L d_model=6144 48H (GQA kv=8)
MoE 16 experts top-4, expert d_ff=10752, vocab=100352."""

from repro.configs.lm_common import lm_archdef
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="dbrx-132b",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=10752,
    vocab=100352,
    qk_norm=False,
    qkv_bias=False,
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752, n_shared=0,
                  first_dense_layers=0),
)

ARCH = lm_archdef(CONFIG, notes="16-expert top-4 MoE GQA "
                                "[hf:databricks/dbrx-base; unverified]")
