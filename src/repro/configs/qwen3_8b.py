"""qwen3-8b [hf:Qwen/Qwen3-8B]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA, no qkv bias."""

from repro.configs.lm_common import lm_archdef
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen3-8b",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
    qkv_bias=False,
    rope_theta=1e6,
)

ARCH = lm_archdef(CONFIG, notes="dense GQA with qk_norm [hf:Qwen/Qwen3-8B]")
