"""deepseek-v2-236b [arXiv:2405.04434]: 60L d_model=5120 128H, MLA
(kv_lora=512, q_lora=1536, rope 64 + nope 128, v 128), MoE: 2 shared + 160
routed experts top-6, expert d_ff=1536, vocab=102400, first layer dense."""

from repro.configs.lm_common import lm_archdef
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-v2-236b",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=192,  # qk head dim (nope 128 + rope 64)
    d_ff=12288,  # dense layers (first_dense_layers) use 12288
    vocab=102400,
    attn="mla",
    q_lora=1536,
    kv_lora=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    rope_theta=1e4,
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                  first_dense_layers=1),
)

ARCH = lm_archdef(CONFIG,
                  notes="MLA + fine-grained MoE (2 shared + 160 routed "
                        "top-6) [arXiv:2405.04434]")
