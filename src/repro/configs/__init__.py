"""Architecture registry: ``--arch <id>`` resolution.

10 assigned architectures + the paper's own engine workload."""

from __future__ import annotations

from repro.configs.common import (ArchDef, Cell, GNN_SHAPES, LM_SHAPES,
                                  RECSYS_SHAPES)

_ARCH_MODULES = {
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "minitron-8b": "repro.configs.minitron_8b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "dimenet": "repro.configs.dimenet",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "pna": "repro.configs.pna",
    "gcn-cora": "repro.configs.gcn_cora",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "turbohom": "repro.configs.turbohom",
}

ASSIGNED = tuple(k for k in _ARCH_MODULES if k != "turbohom")


def get_arch(name: str) -> ArchDef:
    import importlib

    mod = _ARCH_MODULES.get(name)
    if mod is None:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(mod).ARCH


def all_archs() -> list[str]:
    return sorted(_ARCH_MODULES)


__all__ = ["ArchDef", "Cell", "get_arch", "all_archs", "ASSIGNED",
           "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]
