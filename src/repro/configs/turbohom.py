"""The paper's own workload as a selectable config: the TurboHOM++ engine
serving LUBM-like query mixes.

Cells describe the distributed query step the dry-run lowers: a chunk of
starting-vertex candidates sharded over (pod × data), the replicated graph
arrays, and a fixed 3-step triangle plan (the Q2/Q9 shape the paper's perf
study centers on).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp

from repro.configs.common import ArchDef, Cell, sds


@dataclass(frozen=True)
class EngineConfig:
    name: str = "turbohom"
    # synthetic graph scale for the dry-run arrays (LUBM8000-like density)
    n_vertices: int = 260_000_000
    n_edges: int = 1_230_000_000
    n_vlabels: int = 32
    n_elabels: int = 18
    cap: int = 1 << 16  # per-device binding-table capacity
    chunk: int = 1 << 14  # starting vertices per device chunk
    n_steps: int = 3  # plan length (triangle)


CONFIG = EngineConfig()

SHAPES = {
    "triangle_q2": dict(kind="engine", cap=1 << 16, chunk=1 << 14),
    "star_q4": dict(kind="engine", cap=1 << 15, chunk=1 << 14, n_steps=4),
}


def input_specs(cell: str) -> dict:
    meta = SHAPES[cell]
    cap = meta["cap"]
    chunk = meta["chunk"]
    c = CONFIG
    return {
        # replicated graph arrays (per-edge-label CSR rows for the plan steps
        # + global neighbor array + label bitmaps)
        "nbr_el": sds((c.n_edges,)),
        "iptr_rows": sds((meta.get("n_steps", c.n_steps), c.n_vertices + 1)),
        "label_bitmap": sds((c.n_vertices, (c.n_vlabels + 31) // 32),
                            jnp.uint32),
        # sharded work: starting candidates per device chunk
        "chunk": sds((chunk,)),
        "chunk_count": sds((), jnp.int32),
    }


def _smoke():
    # engine smoke is covered by the dedicated engine test-suite; here we
    # return a tiny descriptor for the generic harness
    return CONFIG, {}


ARCH = ArchDef(
    name="turbohom",
    family="engine",
    config=CONFIG,
    cells={name: Cell(name, "engine", dict(meta))
           for name, meta in SHAPES.items()},
    input_specs=input_specs,
    smoke=_smoke,
    notes="the paper's engine as a distributed workload; lowered via "
          "core.distributed.query_chunk_step",
)
