"""dimenet [arXiv:2003.03123]: 6 interaction blocks, hidden 128, 8 bilinear,
7 spherical × 6 radial basis functions; molecular energy regression."""

import dataclasses

from repro.configs.gnn_common import gnn_archdef
from repro.models.gnn import dimenet

CONFIG = dimenet.DimeNetConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
    n_radial=6)

SMALL = dataclasses.replace(CONFIG, n_blocks=2, d_hidden=16, n_bilinear=2,
                            n_spherical=3, n_radial=2)

ARCH = gnn_archdef("dimenet", CONFIG, dimenet.loss_fn, SMALL,
                   notes="triplet directional message passing "
                         "[arXiv:2003.03123]; angular basis uses cos(lθ) "
                         "family of the published rank (see DESIGN.md)")
