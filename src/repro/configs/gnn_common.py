"""Shared ArchDef builder + smoke-batch synthesis for the GNN family."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.common import GNN_SHAPES, ArchDef, Cell, gnn_input_specs


def synth_graph_batch(arch: str, cfg, n: int, e: int, n_graphs: int = 1,
                      seed: int = 0) -> dict:
    """Small real batch with the family layout (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    batch = {"edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst)}
    if arch == "dimenet":
        t = min(4 * e, 512)
        batch.update({
            "z": jnp.asarray(rng.integers(0, cfg.n_atom_types, n), jnp.int32),
            "pos": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
            "t_kj": jnp.asarray(rng.integers(0, e, t), jnp.int32),
            "t_ji": jnp.asarray(rng.integers(0, e, t), jnp.int32),
            "batch_seg": jnp.asarray(rng.integers(0, n_graphs, n), jnp.int32),
            "targets": jnp.asarray(rng.normal(size=(n_graphs,)), jnp.float32),
        })
    elif arch == "meshgraphnet":
        batch.update({
            "x": jnp.asarray(rng.normal(size=(n, cfg.d_node_in)), jnp.float32),
            "edge_attr": jnp.asarray(rng.normal(size=(e, cfg.d_edge_in)),
                                     jnp.float32),
            "targets": jnp.asarray(rng.normal(size=(n, cfg.d_out)),
                                   jnp.float32),
        })
    else:
        batch.update({
            "x": jnp.asarray(rng.normal(size=(n, cfg.d_feat)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.n_classes, n), jnp.int32),
            "train_mask": jnp.asarray(rng.random(n) < 0.5),
        })
    return batch


def gnn_archdef(arch_name: str, cfg, loss_fn, small_cfg, notes="") -> ArchDef:
    cells = {name: Cell(name, meta["kind"], dict(meta))
             for name, meta in GNN_SHAPES.items()}

    def specs(cell_name: str):
        return gnn_input_specs(arch_name, cfg, cell_name)

    def smoke():
        batch = synth_graph_batch(arch_name, small_cfg, n=40, e=120,
                                  n_graphs=4)
        return small_cfg, batch

    def cell_config(cell_name: str):
        """Input width follows the shape cell (d_feat differs per dataset)."""
        s = GNN_SHAPES[cell_name]
        d_feat = s.get("d_feat", 16)
        if hasattr(cfg, "d_feat"):
            return dataclasses.replace(cfg, d_feat=d_feat)
        if hasattr(cfg, "d_node_in"):
            return dataclasses.replace(cfg, d_node_in=d_feat)
        return cfg  # dimenet: atom-type embeddings, no raw feature width

    return ArchDef(name=arch_name, family="gnn", config=cfg, cells=cells,
                   input_specs=specs, smoke=smoke, loss_fn=loss_fn,
                   notes=notes, cell_config=cell_config)
