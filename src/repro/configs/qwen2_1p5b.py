"""qwen2-1.5b [arXiv:2407.10671]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA with QKV bias."""

from repro.configs.lm_common import lm_archdef
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen2-1.5b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    qk_norm=False,
    qkv_bias=True,
    rope_theta=1e6,
)

ARCH = lm_archdef(CONFIG, notes="dense GQA with QKV bias [arXiv:2407.10671]")
