"""gcn-cora [arXiv:1609.02907]: 2 layers, hidden 16, mean/sym-norm aggregate.

d_feat/n_classes follow the active shape cell (cora defaults here)."""

import dataclasses

from repro.configs.gnn_common import gnn_archdef
from repro.models.gnn import gcn

CONFIG = gcn.GCNConfig(
    name="gcn-cora", n_layers=2, d_hidden=16, d_feat=1433, n_classes=7)

SMALL = dataclasses.replace(CONFIG, d_feat=12, n_classes=4)

ARCH = gnn_archdef("gcn-cora", CONFIG, gcn.loss_fn, SMALL,
                   notes="2-layer sym-norm GCN [arXiv:1609.02907]")
