"""Shared ArchDef builder for the LM family."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.common import LM_SHAPES, ArchDef, Cell, lm_input_specs
from repro.models import transformer
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def lm_archdef(cfg: LMConfig, notes: str = "") -> ArchDef:
    cells = {name: Cell(name, meta["kind"], dict(meta))
             for name, meta in LM_SHAPES.items()}

    def specs(cell_name: str):
        return lm_input_specs(cfg, cell_name)

    def smoke():
        small_moe = None
        if cfg.moe is not None:
            small_moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                                  n_shared=min(1, cfg.moe.n_shared),
                                  first_dense_layers=min(
                                      1, cfg.moe.first_dense_layers))
        small = dataclasses.replace(
            cfg, n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=2 if cfg.attn == "gqa" else 4,
            d_head=16, d_ff=128, vocab=256, moe=small_moe,
            q_lora=32, kv_lora=16, rope_head_dim=8, nope_head_dim=16,
            v_head_dim=16, remat=False)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32),
        }
        return small, batch

    return ArchDef(
        name=cfg.name,
        family="lm",
        config=cfg,
        cells=cells,
        input_specs=specs,
        smoke=smoke,
        loss_fn=transformer.loss_fn,
        notes=notes,
    )
