"""Per-vertex neighborhood-signature index.

``sig[v]`` packs, as uint32 words, the set of edge labels incident to data
vertex ``v`` — outgoing labels in words ``[0, W)``, incoming labels in
words ``[W, 2W)``.  Labels are *hash-folded* onto ``n_bits = min(max(
n_elabels, 1), SIG_MAX_BITS)`` bits via ``el % n_bits``, so the index
width is bounded on graphs with huge predicate vocabularies.  Folding
preserves the pruning contract: if a data vertex really has every
predicate a query vertex requires, its folded signature is a superset of
the folded required signature — a failed superset test can only mean a
genuinely missing predicate.  Collisions cost false *positives* only;
pruning never drops a valid match.

Live-store snapshots get a conservative over-approximation
(:func:`signature_rows`): base rows extended with zero rows for
delta-born vertices, insert bits OR-ed in, tombstones ignored.  Exact
signatures are restored at compaction by :func:`patch_index`, which
recomputes only the rows of vertices touched by the delta (asserted
bit-identical to a rebuild in tests).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.rdf.graph import LabeledGraph

SIG_MAX_BITS = 128  # fold predicates onto at most this many bits per direction


def sig_bits(n_elabels: int) -> int:
    return min(max(int(n_elabels), 1), SIG_MAX_BITS)


def _or_edges(sig: np.ndarray, rows: np.ndarray, labels: np.ndarray,
              n_bits: int, word_off: int) -> None:
    """OR the folded bit of each (row, label) pair into ``sig`` in place."""
    t = labels.astype(np.int64) % n_bits
    np.bitwise_or.at(
        sig, (rows, word_off + (t >> 5)),
        np.uint32(1) << (t & 31).astype(np.uint32),
    )


@dataclass
class SignatureIndex:
    """Frozen per-vertex signature table for one :class:`LabeledGraph`."""

    graph: LabeledGraph
    n_bits: int
    sig: np.ndarray  # uint32 [V, 2*W]: out words then in words

    @property
    def n_words(self) -> int:
        """Words per direction."""
        return self.sig.shape[1] // 2

    @staticmethod
    def build(g: LabeledGraph) -> "SignatureIndex":
        n_bits = sig_bits(g.n_elabels)
        w = (n_bits + 31) // 32
        sig = np.zeros((g.n_vertices, 2 * w), dtype=np.uint32)
        for d, off in ((g.out, 0), (g.inc, w)):
            rows = np.repeat(np.arange(g.n_vertices, dtype=np.int64),
                             np.diff(d.indptr_all))
            if rows.size:
                _or_edges(sig, rows, d.lab_all, n_bits, off)
        return SignatureIndex(g, n_bits, sig)

    def dev(self):
        """The table as a device array (cached; plan-time pruning probes
        run through the ``signature_filter`` kernel dispatch)."""
        dev = getattr(self, "_dev", None)
        if dev is None:
            import jax.numpy as jnp

            dev = jnp.asarray(self.sig)
            self._dev = dev  # type: ignore[attr-defined]
        return dev


def get_index(g) -> SignatureIndex:
    """The (cached) signature index of ``g``; snapshots resolve to their
    base graph's index — use :func:`signature_rows` for per-snapshot rows."""
    if getattr(g, "is_snapshot", False):
        return get_index(g.base)
    idx = getattr(g, "_sig_index", None)
    if idx is None or idx.graph is not g:
        idx = SignatureIndex.build(g)
        g._sig_index = idx
    return idx


def signature_rows(g) -> np.ndarray:
    """Per-vertex signature rows for ``g``.

    Plain graphs return the exact index table.  Snapshots return a
    conservative merge: base rows (zero-extended over delta-born
    vertices) with insert bits OR-ed in and tombstones ignored — an
    over-approximation, so superset pruning stays sound across updates.
    """
    if not getattr(g, "is_snapshot", False):
        return get_index(g).sig
    cached = getattr(g, "_sig_rows", None)
    if cached is not None:
        return cached
    idx = get_index(g.base)
    w = idx.n_words
    sig = idx.sig
    n_new = g.n_vertices - g.base.n_vertices
    ins_out, ins_in = g.coo["ins_out"], g.coo["ins_in"]
    if n_new or ins_out.size or ins_in.size:
        sig = np.vstack([sig, np.zeros((n_new, 2 * w), np.uint32)]) \
            if n_new else sig.copy()
        for d, off in ((ins_out, 0), (ins_in, w)):
            if d.size:
                _or_edges(sig, d.key.astype(np.int64), d.el, idx.n_bits, off)
    g._sig_rows = sig  # snapshots are immutable; attr cache is safe
    return sig


def required_signature(n_bits: int, q, u: int,
                       optional_groups: dict[int, int] | None = None
                       ) -> np.ndarray:
    """The folded signature a data vertex must carry to match query vertex
    ``u``: one out-bit per fixed-predicate edge where ``u`` is the subject,
    one in-bit per edge where it is the object.

    Edges reaching into a *different* optional group are skipped — ``u``
    can match with that group's pattern unmatched (left-join semantics),
    so their predicates are not required.  Edges within ``u``'s own group
    or to the required pattern are: any successful binding of ``u``
    implies they hold.
    """
    groups = optional_groups or {}
    gu = groups.get(u, -1)
    w = (n_bits + 31) // 32
    req = np.zeros(2 * w, dtype=np.uint32)
    for e in q.edges:
        if e.elabel < 0:
            continue
        for a, b, off in ((e.u, e.v, 0), (e.v, e.u, w)):
            if a != u:
                continue
            go = groups.get(b, -1)
            if go != -1 and go != gu:
                continue
            t = e.elabel % n_bits
            req[off + (t >> 5)] |= np.uint32(1 << (t & 31))
    return req


def prune_candidates(g, q, u: int, cands: np.ndarray,
                     optional_groups: dict[int, int] | None = None
                     ) -> np.ndarray:
    """Drop candidate vertices whose signature cannot satisfy query vertex
    ``u`` (the planner-side start/restart-candidate prune).  Sound: only
    vertices missing a required predicate are removed."""
    if cands.size == 0:
        return cands
    idx = get_index(g)
    req = required_signature(idx.n_bits, q, u, optional_groups)
    if not req.any():
        return cands
    from repro.kernels import ops as kops

    rows = signature_rows(g)
    keep = np.asarray(kops.signature_filter(
        rows, cands.astype(np.int32), req))
    return cands[keep]


def patch_index(old: SignatureIndex, new_g: LabeledGraph, *,
                ins: np.ndarray, tombs: np.ndarray) -> SignatureIndex:
    """Exact index for the compacted graph: untouched rows carry over,
    rows of vertices incident to any inserted/tombstoned edge are
    recomputed from the new CSR.  Falls back to a full rebuild when the
    fold width changed (predicate vocabulary grew past the old modulus) —
    folded bits are not comparable across widths."""
    n_bits = sig_bits(new_g.n_elabels)
    if n_bits != old.n_bits:
        return SignatureIndex.build(new_g)
    w = old.n_words
    v_old = old.sig.shape[0]
    sig = np.zeros((new_g.n_vertices, 2 * w), dtype=np.uint32)
    sig[:v_old] = old.sig
    parts = [c[:, i] for c in (ins, tombs) if c.size for i in (0, 2)]
    touched = np.unique(np.concatenate(parts)) if parts else \
        np.zeros(0, np.int64)
    if touched.size:
        sig[touched] = 0
        is_touched = np.zeros(new_g.n_vertices, dtype=bool)
        is_touched[touched] = True
        for d, off in ((new_g.out, 0), (new_g.inc, w)):
            rows = np.repeat(np.arange(new_g.n_vertices, dtype=np.int64),
                             np.diff(d.indptr_all))
            m = is_touched[rows]
            if m.any():
                _or_edges(sig, rows[m], d.lab_all[m], n_bits, off)
    return SignatureIndex(new_g, n_bits, sig)
