"""repro.index — neighborhood-signature & summary-graph pruning subsystem.

Two pre-expansion pruning structures in the spirit of TurboHOM++'s
candidate-region exploration and Gai et al.'s summary-graph-driven method:

- :class:`~repro.index.signature.SignatureIndex`: per-vertex packed uint32
  bitmaps of incident predicates per direction (hash-folded superset
  probes, same contract as :mod:`repro.kernels.bitmap_filter`).  A query
  vertex's *required signature* (predicates its data match must have) is
  tested against the index to prune start candidates in the planner and
  expansion frontiers in the executor step loop.
- :class:`~repro.index.summary.SummaryGraph`: a coarse graph over vertex
  classes with per-(class, predicate, class) edge counts; the planner's
  :class:`~repro.core.planner.cost.CostModel` consults it for join
  selectivities instead of the label-frequency heuristic.

Both are built once per :class:`~repro.rdf.graph.LabeledGraph` (cached on
the graph), over-approximated conservatively on live-store snapshots
(insert bits OR-ed in, tombstones ignored — pruning stays sound), and
patched *exactly* at :meth:`VersionedStore.compact` (asserted against a
rebuild in tests, the same contract as ``GraphStats``).
"""

from repro.index.signature import (SignatureIndex, get_index, patch_index,
                                   prune_candidates, required_signature,
                                   signature_rows)
from repro.index.summary import (SummaryGraph, get_summary, patch_summary)

__all__ = [
    "SignatureIndex", "get_index", "patch_index", "prune_candidates",
    "required_signature", "signature_rows",
    "SummaryGraph", "get_summary", "patch_summary",
]
