"""Coarse summary graph over vertex classes.

Every data vertex gets one *primary class* — its smallest vertex label,
or the extra "unlabeled" bucket ``n_vlabels`` — and the summary graph is
the dense count table ``counts[cs, el, co]`` = number of data edges
``s --el--> o`` with ``class(s) = cs`` and ``class(o) = co``.  The
planner's cost model divides by the parent class's population to get
*expected rows per input row* for a join — a per-(class, predicate,
class) selectivity that replaces the global label-frequency discount
whenever both endpoints of a query edge carry labels.

The dense table is bounded by :data:`MAX_DENSE_CELLS`; graphs whose
``(n_vlabels + 1)^2 * n_elabels`` exceeds it simply get no summary
(``build`` returns ``None``) and the cost model falls back to label
frequencies — estimates only, never correctness.

Snapshots consult their base graph's summary (estimate drift across a
delta is tolerated, exactly like ``GraphStats``); compaction patches the
table exactly via :func:`patch_summary`: delta edges are applied at old
classes, then one masked pass over the new CSR re-keys the edges whose
endpoint classes changed.  Tests assert the patch equals a rebuild.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.rdf.graph import LabeledGraph

MAX_DENSE_CELLS = 1 << 22  # dense (C, n_el, C) int64 table bound (~32 MB)

_MISSING = object()  # cache sentinel: "build was attempted, returned None"


def primary_classes(g: LabeledGraph) -> np.ndarray:
    """Smallest label per vertex; ``n_vlabels`` for label-free vertices."""
    classes = np.full(g.n_vertices, g.n_vlabels, dtype=np.int32)
    for li in range(g.n_vlabels - 1, -1, -1):
        has = (g.label_bitmap[:, li >> 5] >> np.uint32(li & 31)) & np.uint32(1)
        classes[has.astype(bool)] = li
    return classes


@dataclass
class SummaryGraph:
    graph: LabeledGraph
    n_classes: int  # n_vlabels + 1 (last class = unlabeled bucket)
    classes: np.ndarray  # int32 [V] primary class per vertex
    counts: np.ndarray  # int64 [C, n_el, C] edge counts
    class_count: np.ndarray  # int64 [C] vertices per class

    @staticmethod
    def build(g: LabeledGraph) -> "SummaryGraph | None":
        c = g.n_vlabels + 1
        ne = max(1, g.n_elabels)
        if c * c * ne > MAX_DENSE_CELLS:
            return None
        classes = primary_classes(g)
        counts = np.zeros((c, ne, c), dtype=np.int64)
        rows = np.repeat(np.arange(g.n_vertices, dtype=np.int64),
                         np.diff(g.out.indptr_all))
        if rows.size:
            key = ((classes[rows].astype(np.int64) * ne
                    + g.out.lab_all.astype(np.int64)) * c
                   + classes[g.out.nbr_all.astype(np.int64)])
            counts = np.bincount(key, minlength=c * ne * c) \
                .reshape(c, ne, c).astype(np.int64)
        class_count = np.bincount(classes, minlength=c).astype(np.int64)
        return SummaryGraph(g, c, classes, counts, class_count)

    def est_fanout(self, el: int, forward: bool,
                   parent_labels: tuple[int, ...],
                   child_labels: tuple[int, ...]) -> float | None:
        """Expected rows per input row expanding ``el`` from a parent of
        class ``min(parent_labels)`` to children of class
        ``min(child_labels)``; ``None`` when either side is label-free or
        the predicate is unknown to the table (the caller falls back to
        the label-frequency estimate)."""
        if not parent_labels or not child_labels:
            return None
        if el < 0 or el >= self.counts.shape[1]:
            return None
        cp, cc = min(parent_labels), min(child_labels)
        if cp >= self.n_classes or cc >= self.n_classes:
            return None
        num = self.counts[cp, el, cc] if forward else self.counts[cc, el, cp]
        den = self.class_count[cp]
        if den <= 0:
            return 0.0
        return float(num) / float(den)


def get_summary(g) -> SummaryGraph | None:
    """The (cached) summary graph of ``g`` — ``None`` when the class space
    is too large to summarize.  Snapshots resolve to their base graph."""
    if getattr(g, "is_snapshot", False):
        return get_summary(g.base)
    s = getattr(g, "_summary_graph", _MISSING)
    if s is _MISSING or (s is not None and s.graph is not g):
        s = SummaryGraph.build(g)
        g._summary_graph = s
    return s


def patch_summary(old: SummaryGraph | None, new_g: LabeledGraph, *,
                  ins: np.ndarray, tombs: np.ndarray,
                  label_changes) -> SummaryGraph | None:
    """Exact summary for the compacted graph.

    Two phases keep it O(|delta| + |edges touching re-classed vertices|):
    (a) inserted/tombstoned edges are counted in/out at *old* endpoint
    classes, turning the old-graph table into the new-edge-set table
    under old classes; (b) one masked pass over the new out-CSR re-keys
    every edge incident to a vertex whose class changed.  New vertices
    take their new class in both phases, so phase (b) never touches them.
    """
    if old is None:
        return None
    c = old.n_classes
    if c != new_g.n_vlabels + 1:  # label space changed: classes incomparable
        return SummaryGraph.build(new_g)
    ne = max(1, new_g.n_elabels)
    if c * c * ne > MAX_DENSE_CELLS:
        return None
    counts = old.counts
    if ne > counts.shape[1]:
        counts = np.concatenate(
            [counts, np.zeros((c, ne - counts.shape[1], c), np.int64)],
            axis=1)
    else:
        counts = counts.copy()

    v_old = old.classes.shape[0]
    oc = np.concatenate([old.classes,
                         np.full(new_g.n_vertices - v_old, c - 1, np.int32)])
    nc = oc.copy()
    for vid, _old_ls, new_ls in label_changes:
        nc[vid] = min(new_ls) if new_ls else c - 1
    oc[v_old:] = nc[v_old:]  # new vertices: "old" class := new class

    flat = counts.reshape(-1)
    for coo3, sign in ((ins, 1), (tombs, -1)):
        if coo3.size:
            s, el, o = (coo3[:, i].astype(np.int64) for i in range(3))
            key = (oc[s].astype(np.int64) * ne + el) * c + oc[o]
            flat += sign * np.bincount(key, minlength=flat.size)

    changed = oc != nc
    if changed.any():
        rows = np.repeat(np.arange(new_g.n_vertices, dtype=np.int64),
                         np.diff(new_g.out.indptr_all))
        if rows.size:
            w = new_g.out.nbr_all.astype(np.int64)
            el = new_g.out.lab_all.astype(np.int64)
            m = changed[rows] | changed[w]
            if m.any():
                rows, w, el = rows[m], w[m], el[m]
                flat -= np.bincount(
                    (oc[rows].astype(np.int64) * ne + el) * c + oc[w],
                    minlength=flat.size)
                flat += np.bincount(
                    (nc[rows].astype(np.int64) * ne + el) * c + nc[w],
                    minlength=flat.size)

    class_count = np.bincount(nc, minlength=c).astype(np.int64)
    return SummaryGraph(new_g, c, nc, counts, class_count)
