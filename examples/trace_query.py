"""Trace a SPARQL query end to end: parse -> plan -> compile -> per-chunk
dispatch -> per-step kernels, then print the span tree and write Chrome
trace_event JSON for chrome://tracing / https://ui.perfetto.dev.

    PYTHONPATH=src python examples/trace_query.py
    PYTHONPATH=src python examples/trace_query.py --query Q8 --out trace.json

``--workload-report`` additionally drives the standard LUBM+BSBM query
mix through the serving stack with q-error feedback enabled and prints
the merged workload report (the offline analogue of ``GET
/debug/workload``; see ``python -m repro.obs.report --help``).
"""

import argparse
import json

from repro.core import SparqlEngine
from repro.obs import chrome_trace
from repro.rdf.generator import generate_lubm
from repro.rdf.transform import type_aware_transform
from repro.rdf.workloads import LUBM_QUERIES

ap = argparse.ArgumentParser()
ap.add_argument("--query", default="Q2", choices=sorted(LUBM_QUERIES))
ap.add_argument("--scale", type=int, default=2)
ap.add_argument("--out", default=None, help="write Chrome trace JSON here")
ap.add_argument("--workload-report", action="store_true",
                help="also run the mini LUBM+BSBM workload with q-error "
                     "feedback enabled and print the markdown report")
args = ap.parse_args()

graph, maps = type_aware_transform(
    generate_lubm(scale=args.scale, seed=0, density=0.6).finalize())
engine = SparqlEngine(graph, maps)

# First traced run: plan-cache miss, fresh XLA compiles show up as
# "compile" spans.  trace=True forces profiled execution, so step spans
# carry real device wall times.
res = engine.query(LUBM_QUERIES[args.query], trace=True)
trace = res.stats["trace_obj"]


def show(span, depth=0):
    meta = ", ".join(f"{k}={v}" for k, v in (span.meta or {}).items()
                     if k in ("kernel", "step", "chunk", "hit", "rows",
                              "kept", "model_ms"))
    print(f"{'  ' * depth}{span.name:<14} {span.dur * 1e3:9.3f} ms"
          f"{'  [' + meta + ']' if meta else ''}")
    for child in span.children:
        show(child, depth + 1)


print(f"{args.query}: {res.count} rows, wall {trace.dur_ms:.1f} ms, "
      f"spans account for {trace.span_sum_ms():.1f} ms\n")
show(trace.root)

# Second run hits the plan cache and the compiled-chunk cache: the same
# query now shows "dispatch" spans instead of "compile".
res2 = engine.query(LUBM_QUERIES[args.query], trace=True)
trace2 = res2.stats["trace_obj"]
print(f"\nsecond run (all caches warm): wall {trace2.dur_ms:.1f} ms, "
      f"compiles={len(trace2.find('compile'))}, "
      f"dispatches={len(trace2.find('dispatch'))}")

if args.out:
    with open(args.out, "w") as f:
        json.dump(chrome_trace([trace, trace2]), f)
    print(f"\nChrome trace written to {args.out} "
          "(open in chrome://tracing or ui.perfetto.dev)")

# Mini workload report: many queries, aggregated — which shapes the
# planner misestimates (q-error), what got pruned, what was re-planned
# from observed cardinalities.
if args.workload_report:
    from repro.obs.report import demo_report, render_markdown

    print("\nrunning mini LUBM+BSBM workload (feedback enabled) ...\n")
    print(render_markdown(demo_report()))
