"""Quickstart: build an RDF graph, run SPARQL queries through TurboHOM++.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import ExecOpts, SparqlEngine
from repro.rdf.generator import generate_lubm
from repro.rdf.transform import type_aware_transform

# 1. a LUBM-like dataset (1 university, ~8k triples)
store = generate_lubm(scale=1, seed=0, density=0.5).finalize()
print(f"dataset: {store.n_triples} triples")

# 2. the paper's type-aware transformation -> labeled graph
graph, maps = type_aware_transform(store)
print(f"graph: {graph.stats()}")

# 3. engine with the TurboHOM++ configuration (+INT, -NLF, -DEG, +REUSE)
engine = SparqlEngine(graph, maps, ExecOpts())

# 4. the paper's Q2 triangle: students + their alma-mater's departments
Q2 = """
SELECT ?x ?y ?z WHERE {
  ?x rdf:type ub:GraduateStudent .
  ?y rdf:type ub:University .
  ?z rdf:type ub:Department .
  ?x ub:memberOf ?z .
  ?z ub:subOrganizationOf ?y .
  ?x ub:undergraduateDegreeFrom ?y .
}"""
res = engine.query(Q2)
print(f"Q2 solutions: {res.count}")
for row in res.decode(maps, limit=3):
    print("  ", row)

# 5. OPTIONAL + FILTER work too
Q_OPT = """
SELECT ?prof ?name ?phone WHERE {
  ?prof rdf:type ub:FullProfessor .
  ?prof ub:name ?name .
  OPTIONAL { ?prof ub:telephone ?phone . }
}"""
res = engine.query(Q_OPT)
print(f"professors: {res.count} (some without phones)")

# 6. subgraph-isomorphism semantics are one flag away (§2.2 of the paper)
iso_engine = SparqlEngine(graph, maps, ExecOpts(semantics="iso"))
print(f"Q2 under injective semantics: {iso_engine.query(Q2).count}")

# 7. EXPLAIN: the cost-based planner's matching order + per-step estimates
plan = engine.explain(Q2)
br = plan["branches"][0]
print(f"Q2 plan ({br['search']} search, start {br['start_vertex']}, "
      f"{br['start_candidates']} candidates):")
for step in br["steps"]:
    print(f"   bind {step['var']:<4} via {step.get('predicate', '?')} "
          f"fanout~{step['est_fanout']} rows~{step['est_rows']}")
