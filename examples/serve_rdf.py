"""End-to-end driver: build a billion-triple-shaped (scaled-down) dataset and
serve a batched SPARQL workload with latency statistics — the paper's
deployment story (in-memory RDF accelerator).

    PYTHONPATH=src python examples/serve_rdf.py [--scale 2]
"""

import argparse

from repro.launch.serve import QueryService, build_dataset
from repro.rdf.workloads import LUBM_QUERIES

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=2)
ap.add_argument("--rounds", type=int, default=5)
args = ap.parse_args()

graph, maps, _ = build_dataset("lubm", args.scale, density=0.6)
print("graph:", graph.stats())
svc = QueryService(graph, maps)

# mixed workload: every LUBM query, several rounds (first round pays
# plan compilation; the compiled-plan cache serves the rest)
for r in range(args.rounds):
    for name, q in sorted(LUBM_QUERIES.items()):
        res, ms = svc.execute(q)
        if r == 0:
            print(f"round0 {name:4s} count={res.count:7d} {ms:8.1f}ms (cold)")
print("\nservice stats (all rounds):", svc.stats())
