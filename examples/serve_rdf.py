"""End-to-end serving demo: build a scaled-down LUBM dataset, host it in
the repro.serve subsystem (registry + coalescing scheduler + HTTP), drive a
concurrent workload, and show one HTTP round-trip — the paper's in-memory
RDF accelerator deployed as a service.

    PYTHONPATH=src python examples/serve_rdf.py [--scale 2] [--rounds 5]
"""

import argparse
import json
import threading
import urllib.request
from urllib.parse import urlencode

from repro.launch.serve import build_dataset
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Scheduler
from repro.serve.server import DatasetRegistry, make_server, serve_in_thread

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=2)
ap.add_argument("--rounds", type=int, default=5)
ap.add_argument("--clients", type=int, default=4)
args = ap.parse_args()

graph, maps, queries = build_dataset("lubm", args.scale, density=0.6)
print("graph:", graph.stats())

registry = DatasetRegistry(ServeMetrics())
registry.register("lubm", graph, maps)
scheduler = Scheduler(registry, workers=4,
                      metrics=registry.metrics).start()

# mixed workload: every LUBM query, several rounds, N concurrent clients
# (round 0 pays plan compilation; the fingerprint-keyed plan cache and
# request coalescing serve the rest)
def client(tid: int) -> None:
    for r in range(args.rounds):
        for name, q in sorted(queries.items()):
            res = scheduler.submit("lubm", q)
            if r == 0 and tid == 0:
                print(f"round0 {name:4s} count={res.count:7d}")

threads = [threading.Thread(target=client, args=(i,))
           for i in range(args.clients)]
for t in threads:
    t.start()
for t in threads:
    t.join()

print("\nservice stats (all rounds):",
      json.dumps(registry.metrics.summary(), indent=None))
print("plan cache:", registry.get("lubm").engine.plan_cache.snapshot())

# same engine over HTTP: one round-trip against the bundled server
server = make_server(registry, port=0, scheduler=scheduler)
serve_in_thread(server)
host, port = server.server_address[:2]
url = f"http://{host}:{port}/sparql?" + urlencode(
    {"query": queries["Q1"], "dataset": "lubm", "limit": 3})
with urllib.request.urlopen(url, timeout=30) as r:
    print("\nHTTP /sparql:", json.dumps(json.loads(r.read()), indent=None))
server.shutdown()
scheduler.stop()
