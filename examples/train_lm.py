"""Train a small qwen3-style LM for a few hundred steps with the full
fault-tolerant loop (checkpoints, resumable stream, straggler tracking).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.models import transformer
from repro.train.data import TokenStream
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.trainstep import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--ckpt", default="runs/example_lm")
args = ap.parse_args()

# reduced qwen3 geometry (same code path as the full config)
cfg, _ = get_arch("qwen3-8b").smoke()
cfg = dataclasses.replace(cfg, vocab=512)

params = transformer.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
opt_state = adamw_init(params, opt_cfg)
stream = TokenStream(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
step = jax.jit(make_train_step(transformer.loss_fn, cfg, opt_cfg))

trainer = Trainer(step, stream,
                  LoopConfig(total_steps=args.steps, ckpt_every=50,
                             ckpt_dir=args.ckpt, log_every=20),
                  params, opt_state)
end = trainer.fit()
print(f"finished at step {end}")
print("last metrics:", trainer.metrics_log[-1])
print("median step time:", f"{trainer.tracker.median * 1e3:.1f}ms")
print("checkpoints kept:", trainer.ckpt.all_steps())
