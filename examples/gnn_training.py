"""Train GCN with real neighbor sampling (the minibatch_lg regime, scaled
down), sharing the engine's CSR machinery.

    PYTHONPATH=src python examples/gnn_training.py --steps 100
"""

import argparse
import dataclasses

import jax

from repro.models.gnn import gcn
from repro.train.data import SampledGraphStream
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.trainstep import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=100)
args = ap.parse_args()

cfg = gcn.GCNConfig(name="gcn-example", n_layers=2, d_hidden=32, d_feat=16,
                    n_classes=5)
stream = SampledGraphStream(n_nodes=3000, avg_degree=8, d_feat=cfg.d_feat,
                            n_classes=cfg.n_classes, batch_nodes=64,
                            fanout=[5, 3], seed=0)
params = gcn.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = OptConfig(lr=5e-3, warmup_steps=10, total_steps=args.steps,
                    weight_decay=0.0)
step = jax.jit(make_train_step(gcn.loss_fn, cfg, opt_cfg))
trainer = Trainer(step, stream,
                  LoopConfig(total_steps=args.steps, ckpt_every=50,
                             ckpt_dir="runs/example_gnn", log_every=20),
                  params, adamw_init(params, opt_cfg))
trainer.fit()
print("last metrics:", trainer.metrics_log[-1])
